"""Index library: exactness of FLAT, recall thresholds for ANN indexes,
save/load, MVCC valid-masks, attribute filtering, auto-tuning."""

import numpy as np
import pytest

from repro.core.collection import Metric
from repro.index import FlatIndex, IndexSpec, create_index
from repro.index.attribute import FilterExpr, LabelIndex, SortedListIndex


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    # clustered data (ANN-friendlier than pure gaussian, like SIFT)
    centers = rng.standard_normal((20, 32)) * 4
    base = (centers[rng.integers(0, 20, 4000)] + rng.standard_normal((4000, 32))).astype(np.float32)
    queries = (centers[rng.integers(0, 20, 16)] + rng.standard_normal((16, 32))).astype(np.float32)
    return base, queries


def brute_force(base, queries, k, metric=Metric.L2):
    if metric is Metric.L2:
        d = np.sum(queries**2, 1, keepdims=True) - 2 * queries @ base.T + np.sum(base**2, 1)
        return np.argsort(d, axis=1)[:, :k]
    return np.argsort(-(queries @ base.T), axis=1)[:, :k]


def recall_of(idx, gt):
    hits = sum(len(set(idx[r].tolist()) & set(gt[r].tolist())) for r in range(len(gt)))
    return hits / gt.size


def test_flat_is_exact(data):
    base, queries = data
    gt = brute_force(base, queries, 10)
    flat = FlatIndex(metric=Metric.L2)
    flat.build(base)
    _s, i = flat.search(queries, 10)
    assert recall_of(i, gt) == 1.0


CASES = [
    ("sq", {}, 0.95),
    ("ivf_flat", {"nlist": 32, "nprobe": 8}, 0.80),
    ("ivf_sq", {"nlist": 32, "nprobe": 8}, 0.75),
    ("ivf_pq", {"nlist": 16, "nprobe": 8, "m": 8}, 0.35),
    ("pq", {"m": 8}, 0.35),
    ("opq", {"m": 8}, 0.35),
    ("hnsw", {"m": 16, "ef_construction": 100, "ef_search": 128}, 0.80),
    ("bucket", {"target_bucket_rows": 96, "replicas": 2, "nprobe_buckets": 16}, 0.70),
]


@pytest.mark.parametrize("kind,params,min_recall", CASES)
def test_index_recall_and_roundtrip(data, kind, params, min_recall):
    base, queries = data
    k = 10
    gt = brute_force(base, queries, k)
    idx = create_index(IndexSpec(kind=kind, metric=Metric.L2, params=params))
    idx.build(base)
    s, i = idx.search(queries, k)
    r = recall_of(i, gt)
    assert r >= min_recall, f"{kind} recall {r} < {min_recall}"
    # serialization roundtrip is bit-identical in results
    idx2 = type(idx).load(idx.save())
    s2, i2 = idx2.search(queries, k)
    np.testing.assert_array_equal(i, i2)


def test_ip_metric(data):
    base, queries = data
    gt = brute_force(base, queries, 10, Metric.IP)
    idx = create_index(IndexSpec(kind="ivf_flat", metric=Metric.IP,
                                 params={"nlist": 32, "nprobe": 16}))
    idx.build(base)
    _s, i = idx.search(queries, 10)
    assert recall_of(i, gt) >= 0.7


def test_valid_mask_filters_results(data):
    base, queries = data
    valid = np.zeros(len(base), bool)
    valid[: len(base) // 2] = True
    for kind, params, _r in CASES[:4]:
        idx = create_index(IndexSpec(kind=kind, metric=Metric.L2, params=params))
        idx.build(base)
        _s, i = idx.search(queries, 10, valid=valid)
        live = i[i >= 0]
        assert (live < len(base) // 2).all(), f"{kind} leaked masked rows"


def test_hnsw_valid_mask(data):
    base, queries = data
    valid = np.zeros(len(base), bool)
    valid[::2] = True
    idx = create_index(IndexSpec(kind="hnsw", metric=Metric.L2,
                                 params={"m": 8, "ef_construction": 40, "ef_search": 64}))
    idx.build(base)
    _s, i = idx.search(queries, 5, valid=valid)
    live = i[i >= 0]
    assert (live % 2 == 0).all()


# ------------------------------------------------------------- attributes
def test_sorted_list_ranges():
    vals = np.array([5.0, 1.0, 3.0, 9.0, 7.0])
    sl = SortedListIndex(vals)
    np.testing.assert_array_equal(sl.range_mask(lo=3, hi=7), [True, False, True, False, True])
    np.testing.assert_array_equal(sl.range_mask(lo=3, hi=7, lo_open=True, hi_open=True),
                                  [True, False, False, False, False])


def test_label_postings():
    vals = np.array(["a", "b", "a", "c"])
    li = LabelIndex(vals)
    np.testing.assert_array_equal(li.eq_mask("a"), [True, False, True, False])
    np.testing.assert_array_equal(li.in_mask(["b", "c"]), [False, True, False, True])


def test_filter_expr():
    cols = {"price": np.array([10.0, 200.0, 50.0]), "stock": np.array([0, 5, 3])}
    m = FilterExpr("price < 100 and stock > 0").evaluate(cols, 3)
    np.testing.assert_array_equal(m, [False, False, True])
    m = FilterExpr("not (price >= 50)").evaluate(cols, 3)
    np.testing.assert_array_equal(m, [True, False, False])
    m = FilterExpr("100 > price").evaluate(cols, 3)  # flipped comparison
    np.testing.assert_array_equal(m, [True, False, True])
    with pytest.raises(ValueError):
        FilterExpr("__import__('os')")


# --------------------------------------------------------------- autotune
def test_bohb_finds_working_config(data):
    from repro.index.autotune import bohb_tune

    base, queries = data
    res = bohb_tune("ivf_flat", base[:2000], queries[:8], k=10, max_trials=6,
                    min_budget_rows=500, seed=3)
    assert res.best_config["nlist"] in [16, 32, 64, 128, 256]
    assert len(res.trials) == 6
    best_recall = max(t.recall for t in res.trials)
    assert best_recall >= 0.6
