"""End-to-end behaviour test for the paper's system: the full Manu
lifecycle in one scenario — the "video recommendation" running example of
§2 (streaming inserts, bounded-staleness search, deletes + audit via time
travel, transparent failure recovery)."""

import numpy as np

from repro.core import ManuConfig, ManuSystem, Metric


def test_video_recommendation_lifecycle():
    rng = np.random.default_rng(42)
    dim = 48
    manu = ManuSystem(ManuConfig(num_query_nodes=2, num_index_nodes=1,
                                 seal_rows=600, slice_rows=256))
    videos = manu.create_collection("videos", dim=dim, metric=Metric.IP)
    videos.create_index("vector", kind="ivf_flat", params={"nlist": 16, "nprobe": 16})

    # day 0: catalogue ingest (normalized embeddings, IP similarity)
    def embed(n):
        v = rng.standard_normal((n, dim)).astype(np.float32)
        return v / np.linalg.norm(v, axis=1, keepdims=True)

    catalogue = embed(2_400)
    for lo in range(0, len(catalogue), 600):
        videos.insert({"vector": catalogue[lo : lo + 600]})

    user = embed(3)
    # bounded staleness: a recommendation may lag up to 2s
    recs = videos.search(user, limit=10, staleness_ms=2_000.0)
    assert (recs.pks >= 0).all()

    # a fresh upload must be visible to a strong read immediately
    fresh = embed(1)
    videos.insert({"vector": fresh})
    fresh_pk = 2_400
    hit = videos.search(fresh, limit=1, staleness_ms=0.0)
    assert hit.pks[0, 0] == fresh_pk, "strong read must see the new upload"

    # takedowns disappear; time travel for audit still sees them
    before = videos.search(user[:1], limit=5, staleness_ms=0.0)
    takedown = before.pks[0][:2]
    videos.delete(takedown)
    after = videos.search(user[:1], limit=5, staleness_ms=0.0)
    assert not set(takedown.tolist()) & set(after.pks[0].tolist())
    audit = videos.search(user[:1], limit=5, time_travel_ts=before.query_ts)
    assert set(takedown.tolist()) <= set(audit.pks[0].tolist())

    # node failure is transparent to serving
    victim = next(iter(manu.query_coord.assignment.values()))
    manu.kill_query_node(victim)
    manu.recover_failures()
    recovered = videos.search(user[:1], limit=5, staleness_ms=0.0)
    np.testing.assert_array_equal(np.sort(recovered.pks, 1), np.sort(after.pks, 1))

    st = manu.stats()
    assert st["index_builds"] >= 4
