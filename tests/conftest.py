import os
import sys

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (multi-device tests spawn
# subprocesses with their own XLA_FLAGS).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can import the benchmarks package (shared baselines)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
