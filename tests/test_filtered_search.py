"""Filtered-search planner equivalence + chaos suite.

Every strategy the planner can pick — pre-filter (bitmap-masked scan),
post-filter (inflated-k scan then cut), brute (gather the surviving
rows) — and the adaptive default that chooses among them must return
the SAME answer: bit-for-bit identical pk/score arrays across
strategies, and set-identical to a row-wise ``FilterExpr`` oracle
evaluated over the visible rows.  The fuzz axes mirror production
reality: all three metrics, deletes and upserts, time travel, partition
pruning, an in-flight compaction, and a query node dying mid-request.

Collections here carry either no vector index or a flat one, so every
strategy is exact and any divergence is a planner bug, not an ANN
quality artifact.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    FieldSchema,
    FieldType,
    ManuConfig,
    ManuSystem,
    Metric,
    SearchRequest,
)
from repro.index.attribute import FilterExpr

CFG = dict(num_query_nodes=2, seal_rows=200, slice_rows=64, num_shards=2)

# None = adaptive; the fixed overrides are the planner's three classes.
STRATEGIES = (None, "pre", "post", "brute")

# Spread across the selectivity spectrum so each fixed strategy is the
# adaptive pick for at least one expression.
EXPRS = [
    "price < 4",                           # tight -> brute
    "price > 30 and price < 45",           # mid   -> pre
    "price < 92",                          # loose -> post
    "label == 'a'",
    "label != 'b' and price < 55",
    "not (label == 'c') or price >= 90",
]

METRICS = [Metric.L2, Metric.IP, Metric.COSINE]


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def _fresh_data(rng, n, dim):
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    price = rng.uniform(0, 100, n).astype(np.float64)
    label = np.asarray(rng.choice(["a", "b", "c"], n))
    return vecs, price, label


def _make_collection(system, rng, metric=Metric.L2, n=700, dim=8,
                     index=None, growing=50):
    # ``growing`` stays below slice_rows per shard (incl. later upserts) so
    # growing reads take the exact brute-tail path — full slices get a
    # temporary IVF index that is approximate by design (paper 3.6) and
    # would fail the bit-for-bit oracle this suite demands.
    coll = system.create_collection(
        "c", dim=dim, metric=metric,
        extra_fields=[FieldSchema("price", FieldType.FLOAT),
                      FieldSchema("label", FieldType.STRING)],
    )
    if index:
        coll.create_index("vector", kind=index)
    vecs, price, label = _fresh_data(rng, n, dim)
    coll.insert({"vector": vecs, "price": price, "label": label})
    coll.flush()
    if growing:
        gv, gp, gl = _fresh_data(rng, growing, dim)
        coll.insert({"vector": gv, "price": gp, "label": gl})
        vecs = np.concatenate([vecs, gv])
        price = np.concatenate([price, gp])
        label = np.concatenate([label, gl])
    return coll, vecs, price, label


def _oracle_pks(metric, vecs, q, keep, k):
    """Row-wise ground truth: pk ranking of the surviving rows."""
    base = vecs[keep]
    if metric is Metric.L2:
        key = (np.sum(q ** 2, 1, keepdims=True) - 2 * q @ base.T
               + np.sum(base ** 2, 1))
    else:
        b = base
        qq = q
        if metric is Metric.COSINE:
            b = b / np.maximum(np.linalg.norm(b, axis=1, keepdims=True), 1e-12)
            qq = qq / np.maximum(
                np.linalg.norm(qq, axis=1, keepdims=True), 1e-12)
        key = -(qq @ b.T)  # descending similarity
    order = np.argsort(key, axis=1, kind="stable")[:, :k]
    return keep[order]


def _assert_strategies_match(coll, q, k, expr, vecs, cols, live_mask,
                             metric, time_travel_ts=None,
                             partition_names=()):
    """All four strategies agree bit-for-bit and match the row-wise oracle."""
    fmask = FilterExpr(expr).evaluate(cols, len(live_mask))
    keep = np.nonzero(live_mask & fmask)[0]
    want = _oracle_pks(metric, vecs, q, keep, k)
    outs = {}
    for strat in STRATEGIES:
        outs[strat] = coll.search(SearchRequest.single(
            q, k=k, filter=expr, filter_strategy=strat, staleness_ms=0.0,
            time_travel_ts=time_travel_ts, partition_names=partition_names,
        ))
    for strat in ("pre", "post", "brute"):
        np.testing.assert_array_equal(
            outs[None].pks, outs[strat].pks,
            err_msg=f"adaptive vs {strat} diverged on {expr!r}")
        np.testing.assert_array_equal(outs[None].scores, outs[strat].scores)
    res = outs[None]
    for r in range(len(q)):
        live = res.pks[r][res.pks[r] >= 0]
        assert len(set(live.tolist())) == len(live), (expr, "duplicate pks")
        assert set(live.tolist()) == set(want[r][: len(live)].tolist()), (
            expr, sorted(live.tolist()), sorted(want[r][: len(live)].tolist()))
    return res


# --------------------------------------------------------------- fuzz core


@pytest.mark.parametrize("metric", METRICS, ids=[m.value for m in METRICS])
@pytest.mark.parametrize("index", [None, "flat"], ids=["noindex", "flat"])
def test_fuzz_strategies_match_rowwise_oracle(metric, index, rng):
    """Metrics x (indexed | unindexed) x deletes x upserts x growing rows:
    pre == post == brute == adaptive == row-wise oracle, bit for bit."""
    system = ManuSystem(ManuConfig(**CFG))
    coll, vecs, price, label = _make_collection(
        system, rng, metric=metric, index=index)
    n = len(vecs)
    live = np.ones(n, bool)

    # deletes: a random slab of sealed + growing pks
    victims = rng.choice(n, 100, replace=False)
    coll.delete(victims)
    live[victims] = False

    # upserts: replace vectors AND attributes of surviving pks in place
    up = rng.choice(np.nonzero(live)[0], 40, replace=False)
    uv, upr, ul = _fresh_data(rng, len(up), vecs.shape[1])
    coll.upsert({"pk": up, "vector": uv, "price": upr, "label": ul})
    vecs, price, label = vecs.copy(), price.copy(), label.copy()
    vecs[up], price[up], label[up] = uv, upr, ul

    q = rng.standard_normal((3, vecs.shape[1])).astype(np.float32)
    cols = {"pk": np.arange(n), "price": price, "label": label}
    for expr in EXPRS:
        _assert_strategies_match(
            coll, q, 10, expr, vecs, cols, live, metric)


def test_filtered_time_travel_resurrects_rows(rng):
    """A filtered search pinned before a delete sees the deleted rows —
    identically under every strategy."""
    system = ManuSystem(ManuConfig(**CFG))
    coll, vecs, price, label = _make_collection(system, rng, growing=0)
    n = len(vecs)
    cols = {"pk": np.arange(n), "price": price, "label": label}
    q = rng.standard_normal((2, vecs.shape[1])).astype(np.float32)

    pin = coll.search(SearchRequest.single(
        q, k=5, filter="price < 50", staleness_ms=0.0))
    victims = pin.pks[0][pin.pks[0] >= 0][:3]
    coll.delete(victims)

    live_now = np.ones(n, bool)
    live_now[victims] = False
    after = _assert_strategies_match(
        coll, q, 5, "price < 50", vecs, cols, live_now, Metric.L2)
    assert not set(victims.tolist()) & set(after.pks[0].tolist())

    # at the pinned ts every strategy resurrects the victims
    old = _assert_strategies_match(
        coll, q, 5, "price < 50", vecs, cols, np.ones(n, bool), Metric.L2,
        time_travel_ts=pin.query_ts)
    assert set(victims.tolist()) <= set(old.pks[0].tolist())


def test_filtered_search_respects_partitions(rng):
    """Partition pruning composes with the filter: only rows from the
    requested partitions survive, and the strategies still agree."""
    system = ManuSystem(ManuConfig(**CFG))
    coll = system.create_collection(
        "p", dim=8,
        extra_fields=[FieldSchema("price", FieldType.FLOAT),
                      FieldSchema("label", FieldType.STRING)],
    )
    coll.create_partition("hot")
    vecs, price, label = _fresh_data(rng, 600, 8)
    half = 300
    coll.insert({"vector": vecs[:half], "price": price[:half],
                 "label": label[:half]})
    coll.insert({"vector": vecs[half:], "price": price[half:],
                 "label": label[half:]}, partition="hot")
    coll.flush()

    q = rng.standard_normal((2, 8)).astype(np.float32)
    cols = {"pk": np.arange(600), "price": price, "label": label}
    hot_only = np.zeros(600, bool)
    hot_only[half:] = True
    res = _assert_strategies_match(
        coll, q, 8, "price < 70", vecs, cols, hot_only, Metric.L2,
        partition_names=("hot",))
    assert (res.pks[res.pks >= 0] >= half).all()
    # and the unrestricted search sees both partitions
    _assert_strategies_match(
        coll, q, 8, "price < 70", vecs, cols, np.ones(600, bool), Metric.L2)


# ------------------------------------------------------------------ chaos


def test_filtered_search_during_compaction(rng):
    """Strong filtered searches issued between every scheduling round of
    an in-flight compaction: same pk set every round, all strategies."""
    system = ManuSystem(ManuConfig(**CFG))
    coll, vecs, price, label = _make_collection(system, rng, growing=0)
    n = len(vecs)
    live = np.ones(n, bool)
    victims = rng.choice(n, 250, replace=False)
    coll.delete(victims)
    live[victims] = False

    q = rng.standard_normal((2, vecs.shape[1])).astype(np.float32)
    cols = {"pk": np.arange(n), "price": price, "label": label}
    expr = "price < 60 and label != 'c'"
    baseline = _assert_strategies_match(
        coll, q, 10, expr, vecs, cols, live, Metric.L2)

    tasks = system.compaction_coord.plan("c")
    assert tasks
    for _ in range(200):
        res = _assert_strategies_match(
            coll, q, 10, expr, vecs, cols, live, Metric.L2)
        np.testing.assert_array_equal(res.pks, baseline.pks)
        if not system.compaction_coord.pending:
            break
        system.pump()
    assert not system.compaction_coord.pending
    # compaction rebuilt the attribute satellites for the rewritten
    # segments: the planner still has index-backed estimates
    _assert_strategies_match(coll, q, 10, expr, vecs, cols, live, Metric.L2)


def test_kill_node_mid_filtered_search_bit_for_bit(rng):
    """A query node dying between filter planning and the scan: the proxy
    re-dispatches to surviving replicas and the filtered answer is
    bit-for-bit the single-node oracle system's."""
    dim, n = 8, 900
    oracle_sys = ManuSystem(
        ManuConfig(num_query_nodes=1, seal_rows=200, num_shards=2))
    system = ManuSystem(ManuConfig(
        num_query_nodes=3, replication_factor=2, seal_rows=200, num_shards=2))
    rng_a, rng_b = np.random.default_rng(3), np.random.default_rng(3)
    fields = lambda: [FieldSchema("price", FieldType.FLOAT),
                      FieldSchema("label", FieldType.STRING)]
    o_coll = oracle_sys.create_collection("c", dim=dim, extra_fields=fields())
    coll = system.create_collection("c", dim=dim, extra_fields=fields())
    va, pa, la = _fresh_data(rng_a, n, dim)
    vb, pb, lb = _fresh_data(rng_b, n, dim)
    o_coll.insert({"vector": va, "price": pa, "label": la})
    coll.insert({"vector": vb, "price": pb, "label": lb})
    o_coll.flush()
    coll.flush()

    q = np.random.default_rng(9).standard_normal((4, dim)).astype(np.float32)
    req = SearchRequest.single(
        q, k=10, filter="price < 55 and label != 'b'", staleness_ms=0.0)
    oracle = o_coll.search(req)

    victim_id = next(
        nid for nid, st in system.query_coord.nodes.items() if st.segments)
    victim = system.query_nodes[victim_id]

    def dying(request):
        victim.alive = False
        raise RuntimeError("injected crash mid-filtered-search")

    victim.search_request = dying
    res = coll.search(req)
    np.testing.assert_array_equal(
        np.sort(oracle.pks, 1), np.sort(res.pks, 1))
    np.testing.assert_allclose(
        np.sort(oracle.scores, 1), np.sort(res.scores, 1), rtol=1e-5)
    assert victim_id not in system.cluster_state().live_node_ids


# -------------------------------------------------- satellite observability


def test_proxy_filter_parse_cache(rng):
    """The proxy compiles a filter string once per (collection, expr) and
    serves repeats from the LRU — visible through the cache counters."""
    system = ManuSystem(ManuConfig(**CFG))
    coll, vecs, price, label = _make_collection(system, rng, growing=0)
    q = rng.standard_normal((1, vecs.shape[1])).astype(np.float32)

    def counters():
        snap = system.metrics()
        return (snap.counters.get("filter_parse_cache_hit_total", 0),
                snap.counters.get("filter_parse_cache_miss_total", 0))

    h0, m0 = counters()
    for _ in range(4):
        coll.search(SearchRequest.single(
            q, k=5, filter="price < 33", staleness_ms=0.0))
    h1, m1 = counters()
    assert m1 - m0 == 1  # compiled exactly once
    assert h1 - h0 == 3  # every repeat was a hit
    # a different expression is its own entry
    coll.search(SearchRequest.single(
        q, k=5, filter="price < 34", staleness_ms=0.0))
    h2, m2 = counters()
    assert m2 - m1 == 1 and h2 == h1
    # a pre-compiled FilterExpr bypasses the cache entirely
    coll.search(SearchRequest.single(
        q, k=5, filter=FilterExpr("price < 33"), staleness_ms=0.0))
    assert counters() == (h2, m2)


def test_filter_strategy_metrics_and_trace_span(rng):
    """Strategy counters move per planned unit, the estimated-vs-actual
    selectivity gauges are populated, and a traced filtered search carries
    a ``filter_plan`` span naming each segment's chosen strategy."""
    system = ManuSystem(ManuConfig(**CFG))
    coll, vecs, price, label = _make_collection(system, rng, growing=0)
    q = rng.standard_normal((1, vecs.shape[1])).astype(np.float32)

    res = coll.search(SearchRequest.single(
        q, k=5, filter="price < 50", staleness_ms=0.0, trace=True))
    snap = system.metrics()
    strat_total = sum(
        v for k_, v in snap.counters.items()
        if k_.startswith("filter_strategy_total"))
    assert strat_total >= 1
    assert any(k_.startswith("filter_selectivity_est") for k_ in snap.gauges)
    assert any(k_.startswith("filter_selectivity_actual") for k_ in snap.gauges)

    def spans(node):
        yield node
        for c in node.children:
            yield from spans(c)

    names = [s.name for s in spans(res.trace.root)]
    assert "filter_plan" in names
    fspan = next(s for s in spans(res.trace.root) if s.name == "filter_plan")
    assert fspan.detail  # "<segment>:<strategy>@<actual-selectivity>" list
    assert any(tag in fspan.detail for tag in (":pre", ":post", ":brute"))
