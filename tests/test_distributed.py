"""Multi-device tests: run in SUBPROCESSES with forced host device counts
(conftest deliberately leaves the main process at 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(script: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_distributed_search_matches_bruteforce():
    run_subprocess("""
    import numpy as np, jax
    from repro.distributed.search import distributed_search_host
    rng = np.random.default_rng(0)
    base = rng.standard_normal((999, 24)).astype(np.float32)   # uneven => pad path
    q = rng.standard_normal((4, 24)).astype(np.float32)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    vals, idx = distributed_search_host(q, base, 10, "l2", mesh)
    d2 = np.sum(q**2,1,keepdims=True) - 2*q@base.T + np.sum(base**2,1)
    gt = np.argsort(d2,axis=1)[:, :10]
    assert (np.sort(idx,1) == np.sort(gt,1)).all()
    print("OK")
    """)


def test_flash_decode_matches_dense():
    """The shard_map flash-decode over a sequence-sharded KV cache must be
    numerically equivalent to dense decode attention."""
    run_subprocess("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.model import dense_gqa_decode_attn
    from repro.distributed.decode_attn import make_gqa_flash_decode

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    B, S, H, KVH, hd = 4, 32, 8, 2, 16
    rng = np.random.default_rng(0)
    q = rng.standard_normal((B, 1, H, hd)).astype(np.float32)
    k_new = rng.standard_normal((B, 1, KVH, hd)).astype(np.float32)
    v_new = rng.standard_normal((B, 1, KVH, hd)).astype(np.float32)
    kc = rng.standard_normal((B, S, KVH, hd)).astype(np.float32)
    vc = rng.standard_normal((B, S, KVH, hd)).astype(np.float32)
    pos = jnp.asarray(17, jnp.int32)

    ref_out, ref_k, ref_v = dense_gqa_decode_attn(
        jnp.asarray(q), jnp.asarray(k_new), jnp.asarray(v_new),
        jnp.asarray(kc), jnp.asarray(vc), pos)

    impl = make_gqa_flash_decode(mesh, "model", P("data"))
    with mesh:
        put = lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec))
        out, k2, v2 = jax.jit(impl)(
            put(q, P("data")), put(k_new, P("data")), put(v_new, P("data")),
            put(kc, P("data", "model")), put(vc, P("data", "model")), pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(ref_k), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(ref_v), rtol=1e-5, atol=1e-5)
    print("OK")
    """)


def test_mla_flash_decode_matches_dense():
    run_subprocess("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.model import dense_mla_decode_attn
    from repro.distributed.decode_attn import make_mla_flash_decode

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    B, S, H, r, rope = 4, 32, 6, 16, 8
    rng = np.random.default_rng(0)
    q_c = rng.standard_normal((B, 1, H, r)).astype(np.float32)
    q_rope = rng.standard_normal((B, 1, H, rope)).astype(np.float32)
    payload = rng.standard_normal((B, 1, r + rope)).astype(np.float32)
    cc = rng.standard_normal((B, S, r + rope)).astype(np.float32)
    pos = jnp.asarray(9, jnp.int32)

    ref_ctx, ref_c = dense_mla_decode_attn(
        jnp.asarray(q_c), jnp.asarray(q_rope), jnp.asarray(payload),
        jnp.asarray(cc), pos, r, 24)

    impl = make_mla_flash_decode(mesh, "model", P("data"))
    with mesh:
        put = lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec))
        ctx, c2 = jax.jit(lambda a,b,c,d,e: impl(a,b,c,d,e,r,24))(
            put(q_c, P("data")), put(q_rope, P("data")), put(payload, P("data")),
            put(cc, P("data", "model")), pos)
    np.testing.assert_allclose(np.asarray(ctx), np.asarray(ref_ctx), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(ref_c), rtol=1e-5, atol=1e-5)
    print("OK")
    """)


def test_small_mesh_train_step_executes():
    """REAL multi-device execution of a full sharded train step (reduced
    arch, 2x2 mesh) — proves the partition specs are executable, not just
    compilable."""
    run_subprocess("""
    import numpy as np, jax, jax.numpy as jnp
    import dataclasses
    from repro.configs import ARCHS
    from repro.models import model as M
    from repro.launch.steps import build_train_cell
    from repro.models.config import ShapeConfig
    from repro.train.optimizer import init_opt_state

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = ARCHS["yi-9b"].reduced(num_heads=4, num_kv_heads=2, d_model=64,
                                 head_dim=16, d_ff=128, vocab_size=256)
    shape = ShapeConfig("tiny_train", seq_len=32, global_batch=4, kind="train")
    step, shardings, structs, donate = build_train_cell(cfg, shape, mesh)

    params = M.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (4, 32), 0, 256),
        "labels": jax.random.randint(jax.random.key(2), (4, 32), 0, 256),
    }
    with mesh:
        params = jax.device_put(params, shardings[0])
        opt = jax.device_put(opt, shardings[1])
        batch = jax.device_put(batch, shardings[2])
        fn = jax.jit(step, in_shardings=shardings, donate_argnums=donate)
        p2, o2, metrics = fn(params, opt, batch)
        loss1 = float(metrics["loss"])
        p3, o3, metrics2 = fn(p2, o2, batch)
        loss2 = float(metrics2["loss"])
    assert np.isfinite(loss1) and np.isfinite(loss2)
    assert loss2 < loss1, (loss1, loss2)
    print("OK", loss1, loss2)
    """, devices=4)


def test_small_mesh_moe_shard_map_matches_dense():
    """Expert-parallel shard_map MoE == dense scatter MoE numerically."""
    run_subprocess("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import ARCHS
    from repro.models.moe import init_moe_params, moe_block
    from repro.distributed import act_sharding

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = ARCHS["qwen3-moe-30b-a3b"].reduced()
    p = init_moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32)
    dense = moe_block(cfg, p, x)  # no policy -> dense path
    with mesh:
        with act_sharding.policy(mesh, ("data",), moe_impl="shard_map"):
            sharded = jax.jit(lambda x: moe_block(cfg, p, x))(x)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sharded),
                               rtol=2e-2, atol=2e-2)
    print("OK")
    """, devices=4)


def test_dryrun_search_compiles_at_scale():
    """Distributed vector search lowers+compiles on the 16x16 mesh."""
    run_subprocess("""
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.distributed.search import dryrun_search
    mesh = make_production_mesh()
    compiled = dryrun_search(mesh, n_rows=256*4096, dim=128, nq=64, k=50)
    from repro.distributed.compat import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    assert cost.get("flops", 0) > 0
    print("OK", cost.get("flops"))
    """, devices=256, timeout=560)
