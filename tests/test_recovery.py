"""Crash-restart recovery from the log backbone (tentpole of the
robustness PR): per-node-class kill/restart, lost-seal reconciliation,
whole-system ``ManuSystem.restart()`` verified bit-for-bit against an
uncrashed oracle (including on ``FileObjectStore``), crash-at-every-step
compaction hot-swap, and the seeded chaos acceptance run."""

import os

import numpy as np
import pytest

from repro.core import ManuConfig, ManuSystem
from repro.core.faults import Crash, FaultInjector
from repro.core.object_store import FileObjectStore


CFG = dict(num_query_nodes=2, seal_rows=100, slice_rows=64, num_shards=2)
#: CI's chaos-matrix job sweeps this; the default matches the local run.
CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "1234"))


@pytest.fixture
def system():
    return ManuSystem(ManuConfig(**CFG))


def ingest(coll, rng, n, dim=8, batch=100):
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    for lo in range(0, n, batch):
        coll.insert({"vector": vecs[lo : lo + batch]})
    return vecs


def live_pks(res):
    return {int(pk) for pk in res.pks.ravel().tolist() if pk >= 0}


# ------------------------------------------------- per-node-class restart


def test_logger_kill_restart(system, rng):
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 150)
    system.kill_logger("logger-0")
    # surviving logger keeps taking writes (proxy routes around the corpse)
    ingest(coll, rng, 50)
    system.restart_logger("logger-0")
    ingest(coll, rng, 50)
    coll.flush()
    assert coll.num_entities() == 250
    # PK allocation continued from the meta-store watermark: all unique
    assert system.meta.get("id_alloc/c")["next"] >= 250
    events = [e.kind for e in system.events()]
    assert "node_killed" in events and "node_restarted" in events


def test_data_node_kill_restart_replays_wal(system, rng):
    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 250)  # 2 sealed (archived) + growing tail
    coll.flush()
    ingest(coll, rng, 50)  # growing rows the dead node loses
    system.kill_data_node("dn-0")
    system.restart_data_node("dn-0")
    coll.flush()  # replayed growing rows seal + archive normally
    assert coll.num_entities() == 300
    q = vecs[:4]
    res = coll.search(q, limit=5, staleness_ms=0.0)
    assert np.array_equal(res.pks[:, 0], np.arange(4))


def test_data_node_crash_between_flush_and_seal_announce(rng):
    """The narrow window the log backbone must close: binlog fully durable,
    ``segment_sealed`` never published.  ``reconcile_sealed`` detects the
    orphan binlog (meta object present, no ``segment/`` record) and
    re-announces it."""
    inj = FaultInjector(seed=0)
    system = ManuSystem(ManuConfig(**CFG), injector=inj)
    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 250)
    sealed_before = len(system.data_coord.sealed_segments("c"))
    # next coord-channel publish is the data node's segment_sealed: the
    # binlog write (object-store puts) has already landed when it fires
    inj.crash_at("log.publish", 1, match="coord")
    system.data_coord.flush("c")
    system.run_until_idle()
    inj.disarm()
    dead = [dn.node_id for dn in system.data_nodes if not dn.alive]
    assert dead == ["dn-0"]
    # the orphan: durable binlog, invisible to the metadata plane
    orphans = [
        m.key for m in system.store.list("binlog/c/")
        if m.key.endswith("/meta")
    ]
    assert len(orphans) > len(system.data_coord.sealed_segments("c"))
    system.restart_data_node("dn-0")  # runs reconcile_sealed
    system.run_until_idle()
    assert len(system.data_coord.sealed_segments("c")) > sealed_before
    assert system.telemetry.counter_value("recovery_seals_reconciled_total") >= 1
    assert [e for e in system.events(kind="seal_reconciled")]
    assert coll.num_entities() == 250
    res = coll.search(vecs[:3], limit=5, staleness_ms=0.0)
    assert np.array_equal(res.pks[:, 0], np.arange(3))


def test_index_node_crash_leaks_claim_restart_clears_it(rng):
    """Crash mid-build leaks the CAS claim (kill -9 runs no cleanup);
    restart releases claims with no ``index/`` meta behind them so the
    build re-runs."""
    inj = FaultInjector(seed=0)
    system = ManuSystem(ManuConfig(**CFG), injector=inj)
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 250)
    coll.flush()
    # first index-file put dies -> claim leaked, no index meta
    inj.crash_at("object_store.put", 1, match="index/")
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 4})
    inj.disarm()
    assert not system.index_nodes[0].alive
    leaked = {
        k: v for k, v in system.meta.scan("index_claim/").items()
        if v.get("owner") == "in-0"
    }
    assert leaked
    system.restart_index_node("in-0")
    system.run_until_idle()
    # every sealed segment ended up indexed
    sealed = system.data_coord.sealed_segments("c")
    built = {k for k in system.meta.scan("index/c/")}
    assert len(built) == len(sealed)


def test_compaction_node_crash_restart_reexecutes(rng):
    inj = FaultInjector(seed=0)
    system = ManuSystem(ManuConfig(**CFG), injector=inj)
    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 400)
    coll.flush()
    coll.delete(np.arange(0, 160))
    before = coll.search(vecs[160:163], limit=8, staleness_ms=0.0)
    # die on the first rewritten-binlog put: task claimed, nothing swapped
    inj.crash_at("object_store.put", 1, match="binlog/")
    coll.compact()
    inj.disarm()
    assert not system.compaction_nodes[0].alive
    assert system.compaction_coord.pending  # task survives the crash
    system.restart_compaction_node("cn-0")
    system.run_until_idle()
    assert not system.compaction_coord.pending
    after = coll.search(vecs[160:163], limit=8, staleness_ms=0.0)
    np.testing.assert_array_equal(
        np.sort(before.pks, 1), np.sort(after.pks, 1)
    )
    assert not set(range(160)) & live_pks(after)


def test_query_node_crash_restart(system, rng):
    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 300)
    coll.flush()
    before = coll.search(vecs[:4], limit=5, staleness_ms=0.0)
    system.kill_query_node("qn-0")
    system.restart_query_node("qn-0")
    after = coll.search(vecs[:4], limit=5, staleness_ms=0.0)
    np.testing.assert_array_equal(before.pks, after.pks)
    # the fresh incarnation serves again (reconciler rebalanced onto it)
    assert system.query_nodes["qn-0"].alive


# ------------------------------------------------- whole-system restart


def _workload(system, rng):
    """Two collections, partitions, deletes, an index — returns probes."""
    a = system.create_collection("a", dim=8)
    b = system.create_collection("b", dim=4)
    a.create_partition("hot")
    va = rng.standard_normal((260, 8)).astype(np.float32)
    a.insert({"vector": va[:200]})
    a.insert({"vector": va[200:]}, partition="hot")
    vb = ingest(b, rng, 150, dim=4)
    a.delete(np.arange(0, 40))
    a.flush()
    b.flush()
    a.create_index("vector", kind="ivf_flat", params={"nlist": 4})
    return a, b, va, vb


def _probe(system, va, vb):
    a, b = system.collections["a"], system.collections["b"]
    return (
        a.search(va[40:45], limit=8, staleness_ms=0.0).pks,
        a.search(va[200:203], limit=8, staleness_ms=0.0,
                 partition_names=("hot",)).pks,
        b.search(vb[:5], limit=8, staleness_ms=0.0).pks,
    )


def test_full_restart_bit_for_bit_vs_oracle(rng):
    subject = ManuSystem(ManuConfig(**CFG))
    oracle = ManuSystem(ManuConfig(**CFG))
    seeds = rng.integers(0, 2**31, 2)
    _, _, va_s, vb_s = _workload(subject, np.random.default_rng(seeds[0]))
    _, _, va_o, vb_o = _workload(oracle, np.random.default_rng(seeds[0]))

    report = subject.restart()
    assert report["data"]["sealed"] >= 2
    assert subject.telemetry.counter_value("system_restarts_total") == 1
    assert [e for e in subject.events(kind="system_restarted")]

    for got, want in zip(_probe(subject, va_s, vb_s), _probe(oracle, va_o, vb_o)):
        np.testing.assert_array_equal(got, want)

    # the restarted system is fully live: writes, flushes, searches
    rng2 = np.random.default_rng(seeds[1])
    extra = rng2.standard_normal((30, 8)).astype(np.float32)
    a2 = subject.collections["a"]
    a2.insert({"vector": extra})
    a2.flush()
    assert a2.num_entities() == 290
    # schema/partitions/index specs all came back from meta
    desc = a2.describe()
    assert set(desc.partitions) == {"_default", "hot"}
    assert desc.indexes and desc.indexes[0].kind == "ivf_flat"


def test_full_restart_on_file_object_store(tmp_path, rng):
    """The acceptance bar: restart against a directory-backed store — the
    adaptability story's 'object KV is the local FS' — recovers every
    collection bit-for-bit."""
    subject = ManuSystem(ManuConfig(**CFG), store=FileObjectStore(str(tmp_path)))
    oracle = ManuSystem(ManuConfig(**CFG))
    _, _, va_s, vb_s = _workload(subject, np.random.default_rng(123))
    _, _, va_o, vb_o = _workload(oracle, np.random.default_rng(123))
    before = _probe(subject, va_s, vb_s)
    subject.restart()
    after = _probe(subject, va_s, vb_s)
    want = _probe(oracle, va_o, vb_o)
    for got_b, got_a, w in zip(before, after, want):
        np.testing.assert_array_equal(got_b, got_a)
        np.testing.assert_array_equal(got_a, w)
    # growing (unflushed) rows also survive via WAL replay
    a = subject.collections["a"]
    tail = np.random.default_rng(9).standard_normal((20, 8)).astype(np.float32)
    a.insert({"vector": tail})
    subject.restart()
    assert subject.collections["a"].num_entities() == 280


def test_restart_preserves_pinned_time_travel_reads(rng):
    """Reads pinned before a compaction hot-swap still see the old MVCC
    window after a full restart (retired segments re-loaded + re-retired)."""
    system = ManuSystem(ManuConfig(**CFG))
    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 400)
    coll.flush()
    pinned = coll.search(vecs[:4], limit=8, staleness_ms=0.0)
    assert set(range(4)) <= live_pks(pinned)
    coll.delete(np.arange(0, 160))
    coll.compact()
    system.restart()
    coll = system.collections["c"]
    replay = coll.search(vecs[:4], limit=8, time_travel_ts=pinned.query_ts)
    np.testing.assert_array_equal(
        np.sort(replay.pks, 1), np.sort(pinned.pks, 1)
    )
    now = coll.search(vecs[:4], limit=8, staleness_ms=0.0)
    assert not set(range(160)) & live_pks(now)


def test_wait_timeout_raises_diagnostic_dump(system, rng):
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 50)
    system.compaction_coord.pending["wedge"] = {
        "collection": "c", "targets": [], "sources": [],
    }
    with pytest.raises(TimeoutError) as ei:
        system.wait_idle(timeout_s=0.05)
    msg = str(ei.value)
    assert "wait_idle timed out" in msg
    assert "channel entries" in msg
    assert "compactions=1" in msg
    assert "event " in msg  # last events included
    del system.compaction_coord.pending["wedge"]


# -------------------------------------- crash-at-every-step compaction


def _compaction_scenario(injector=None):
    # single query node: with one shard, the channel owner is the only node
    # guaranteed to see tombstones, so placement must stay on it
    system = ManuSystem(
        ManuConfig(num_query_nodes=1, seal_rows=60, slice_rows=32,
                   num_shards=1, num_loggers=1),
        injector=injector,
    )
    coll = system.create_collection("c", dim=4)
    rng = np.random.default_rng(7)
    vecs = rng.standard_normal((240, 4)).astype(np.float32)
    for lo in range(0, 240, 60):
        coll.insert({"vector": vecs[lo : lo + 60]})
    coll.flush()
    coll.delete(np.arange(0, 96))
    q = vecs[100:103]
    pin = coll.search(q, limit=8, staleness_ms=0.0)
    return system, coll, q, pin


def _recover(system, injector):
    """Post-crash recovery: restart whatever died; a coordinator-path crash
    (Crash escaped ``compact()``) needs the full restart."""
    injector.disarm()
    for lg in system.loggers:
        if not lg.alive:
            system.restart_logger(lg.logger_id)
    for dn in system.data_nodes:
        if not dn.alive:
            system.restart_data_node(dn.node_id)
    for ix in system.index_nodes:
        if not ix.alive:
            system.restart_index_node(ix.node_id)
    for cn in system.compaction_nodes:
        if not cn.alive:
            system.restart_compaction_node(cn.node_id)
    for qn_id, qn in list(system.query_nodes.items()):
        if not qn.alive:
            system.restart_query_node(qn_id)


def test_compaction_crash_at_every_step():
    """Kill the system at EVERY faultable operation inside the compaction
    window (object-store, meta-store and log-broker calls alike), recover,
    and require both the post-compaction state and reads pinned before the
    swap to match a never-crashed oracle exactly."""
    # oracle + op-window enumeration in one run
    probe_inj = FaultInjector(seed=0)
    oracle, ocoll, q, opin = _compaction_scenario(probe_inj)
    window_start = probe_inj.ops
    ocoll.compact()
    window_len = probe_inj.ops - window_start
    oracle_post = ocoll.search(q, limit=8, staleness_ms=0.0)
    oracle_pin_replay = ocoll.search(q, limit=8, time_travel_ts=opin.query_ts)
    np.testing.assert_array_equal(
        np.sort(oracle_pin_replay.pks, 1), np.sort(opin.pks, 1)
    )
    assert window_len > 20

    for op in range(window_start + 1, window_start + window_len + 1):
        inj = FaultInjector(seed=0)
        inj.crash_at_op(op)
        system, coll, q2, pin = _compaction_scenario(inj)
        np.testing.assert_array_equal(pin.pks, opin.pks)
        coordinator_died = False
        try:
            coll.compact()
        except Crash:
            coordinator_died = True
        _recover(system, inj)
        if coordinator_died:
            system.restart()
            coll = system.collections["c"]
        coll.compact()  # drive the interrupted cycle to completion
        post = coll.search(q2, limit=8, staleness_ms=0.0)
        np.testing.assert_array_equal(
            np.sort(post.pks, 1), np.sort(oracle_post.pks, 1),
            err_msg=f"post-compaction divergence at crash op {op}",
        )
        replay = coll.search(q2, limit=8, time_travel_ts=pin.query_ts)
        np.testing.assert_array_equal(
            np.sort(replay.pks, 1), np.sort(opin.pks, 1),
            err_msg=f"pinned-read divergence at crash op {op}",
        )


# ------------------------------------------------------ chaos acceptance


def test_chaos_seeded_kill_every_class_zero_wrong_answers():
    """The PR's acceptance scenario: a seeded chaos run that kills one node
    of every class mid-workload while 10% transient store faults and
    duplicate log delivery fire, and completes with zero wrong search
    answers versus an uncrashed, fault-free oracle."""
    inj = FaultInjector(seed=CHAOS_SEED)
    inj.transient("object_store.put", prob=0.1)
    inj.transient("object_store.get", prob=0.1)
    inj.duplicates(prob=0.05, rewind=2)
    chaos = ManuSystem(ManuConfig(**CFG), injector=inj)
    oracle = ManuSystem(ManuConfig(**CFG))

    wl = np.random.default_rng(99)
    vecs = wl.standard_normal((600, 8)).astype(np.float32)
    price = wl.uniform(0, 100, 600)
    queries = wl.standard_normal((5, 8)).astype(np.float32)
    wrong = 0

    def do(phase, system):
        from repro.core import FieldSchema, FieldType

        coll = (
            system.create_collection(
                "c", dim=8,
                extra_fields=[FieldSchema("price", FieldType.FLOAT)],
            )
            if phase == 0 else system.collections["c"]
        )
        lo = phase * 120
        coll.insert({"vector": vecs[lo : lo + 120],
                     "price": price[lo : lo + 120]})
        if phase == 2:
            coll.delete(np.arange(0, 60))
        if phase == 3:
            coll.flush()
            coll.create_index("vector", kind="flat")
        plain = coll.search(queries, limit=10, staleness_ms=0.0).pks
        # attr satellites ride the same faults: filtered answers count too
        filtered = coll.query(
            queries, limit=10, expr="price < 50", staleness_ms=0.0
        ).pks
        return np.concatenate([plain, filtered], axis=1)

    kills = {
        1: ("kill_logger", "restart_logger", "logger-0"),
        2: ("kill_data_node", "restart_data_node", "dn-0"),
        3: ("kill_query_node", "restart_query_node", "qn-1"),
        4: ("kill_index_node", "restart_index_node", "in-0"),
    }
    for phase in range(5):
        if phase in kills:
            kill, restart, node = kills[phase]
            getattr(chaos, kill)(node)
            getattr(chaos, restart)(node)
        got = do(phase, chaos)
        want = do(phase, oracle)
        wrong += int(not np.array_equal(got, want))
    assert wrong == 0

    counters = chaos.metrics().to_dict()["counters"]
    assert any(k.startswith("faults_injected_total") for k in counters)
    assert any(k.startswith("retry_recovered_total") for k in counters)
    assert any(k.startswith("node_killed_total") for k in counters)
    assert any(k.startswith("node_restarted_total") for k in counters)
    kinds = {e.kind for e in chaos.events()}
    assert {"fault_injected", "node_killed", "node_restarted"} <= kinds


# ------------------------------------------- attribute-index satellites


def _attr_workload(system, rng, n=250):
    from repro.core import FieldSchema, FieldType

    coll = system.create_collection(
        "c", dim=8,
        extra_fields=[FieldSchema("price", FieldType.FLOAT),
                      FieldSchema("label", FieldType.STRING)],
    )
    vecs = rng.standard_normal((n, 8)).astype(np.float32)
    price = rng.uniform(0, 100, n)
    label = np.asarray(rng.choice(["a", "b", "c"], n))
    # batched like ``ingest`` so growing tails remain for flush to seal
    # (a single oversize insert seals its whole batch on the spot)
    for lo in range(0, n, 100):
        coll.insert({"vector": vecs[lo : lo + 100],
                     "price": price[lo : lo + 100],
                     "label": label[lo : lo + 100]})
    return coll, vecs, price, label


def _filtered_probe(coll, vecs, strategy=None):
    from repro.core import SearchRequest

    return coll.search(SearchRequest.single(
        vecs[:3], k=8, filter="price < 60 and label != 'b'",
        filter_strategy=strategy, staleness_ms=0.0,
    ))


def test_crash_between_seal_flush_and_attr_satellite_write(rng):
    """The satellite write window: binlog durable, attribute satellites
    missing (the data node died on the first ``attr/`` put, before the
    ``segment_sealed`` announce).  ``reconcile_sealed`` must rebuild the
    full satellite set from the binlog columns before re-announcing, and
    filtered search must come back bit-for-bit."""
    from repro.core import FieldSchema, FieldType
    from repro.core.binlog import attr_key

    inj = FaultInjector(seed=CHAOS_SEED)
    system = ManuSystem(ManuConfig(**CFG), injector=inj)
    coll, vecs, price, label = _attr_workload(system, rng)

    oracle = ManuSystem(ManuConfig(**CFG))
    ocoll = oracle.create_collection(
        "c", dim=8,
        extra_fields=[FieldSchema("price", FieldType.FLOAT),
                      FieldSchema("label", FieldType.STRING)],
    )
    for lo in range(0, len(vecs), 100):  # mirror the subject's batching
        ocoll.insert({"vector": vecs[lo : lo + 100],
                      "price": price[lo : lo + 100],
                      "label": label[lo : lo + 100]})
    ocoll.flush()

    inj.crash_at("object_store.put", 1, match="attr/")
    system.data_coord.flush("c")
    system.run_until_idle()
    inj.disarm()
    assert [dn.node_id for dn in system.data_nodes if not dn.alive] == ["dn-0"]
    # the window is real: durable binlog metas outnumber announced seals
    orphans = [m.key for m in system.store.list("binlog/c/")
               if m.key.endswith("/meta")]
    assert len(orphans) > len(system.data_coord.sealed_segments("c"))

    system.restart_data_node("dn-0")  # runs reconcile_sealed
    system.run_until_idle()
    assert system.telemetry.counter_value("recovery_seals_reconciled_total") >= 1
    sealed = system.data_coord.sealed_segments("c")
    assert len(sealed) == len(oracle.data_coord.sealed_segments("c"))
    for sid in sealed:  # full satellite set present + meta-recorded
        for f in ("price", "label"):
            assert system.store.exists(attr_key("c", sid, f))
        assert system.meta.scan(f"attr_index/c/{sid}/")

    want = _filtered_probe(ocoll, vecs)
    for strategy in (None, "pre", "post", "brute"):
        got = _filtered_probe(coll, vecs, strategy)
        np.testing.assert_array_equal(got.pks, want.pks)
        np.testing.assert_array_equal(got.scores, want.scores)


def test_restart_heals_vandalized_attr_satellites(rng):
    """``restart()`` detects sealed segments whose satellites are missing
    (segments sealed before the attr subsystem existed, or a partial
    write whose meta never landed) and rebuilds them wholesale."""
    from repro.core.binlog import attr_key

    system = ManuSystem(ManuConfig(**CFG))
    coll, vecs, price, label = _attr_workload(system, rng)
    coll.flush()
    baseline = _filtered_probe(coll, vecs)
    sealed = system.data_coord.sealed_segments("c")
    assert sealed
    for sid in sealed:
        for f in ("price", "label"):
            assert system.store.delete(attr_key("c", sid, f))

    report = system.restart()
    assert report["attr_healed"] == len(sealed)
    assert (system.telemetry.counter_value(
        "recovery_attr_satellites_rebuilt_total") == len(sealed))
    assert [e for e in system.events(kind="attr_satellites_healed")]
    coll = system.collections["c"]
    for sid in sealed:
        for f in ("price", "label"):
            assert system.store.exists(attr_key("c", sid, f))
    after = _filtered_probe(coll, vecs)
    np.testing.assert_array_equal(baseline.pks, after.pks)
    np.testing.assert_array_equal(baseline.scores, after.scores)
    # a second restart finds nothing to heal: the rebuild is convergent
    assert system.restart()["attr_healed"] == 0


def test_gc_reaps_attr_satellites_of_retired_segments(rng):
    """Compaction rewrites carry fresh satellites; GC reclaims the retired
    sources' ``attr/`` objects and ``attr_index/`` meta alongside their
    binlogs — no orphaned satellite survives the sweep."""
    from repro.core.binlog import attr_key

    system = ManuSystem(ManuConfig(**CFG))
    coll, vecs, price, label = _attr_workload(system, rng, n=300)
    coll.flush()
    before = set(system.data_coord.sealed_segments("c"))
    coll.delete(np.arange(0, 120))
    coll.compact()
    coll.gc()

    live = set(system.data_coord.sealed_segments("c"))
    gone = before - live
    assert gone  # the rewrite actually retired sources
    for sid in gone:
        assert not list(system.store.list(f"attr/c/{sid}/"))
        assert not system.meta.scan(f"attr_index/c/{sid}/")
    for sid in live:
        for f in ("price", "label"):
            assert system.store.exists(attr_key("c", sid, f))
        assert system.meta.scan(f"attr_index/c/{sid}/")
