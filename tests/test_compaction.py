"""Compaction & GC subsystem: delete-ratio purge, small-segment merging,
MVCC-safe hot-swap, tombstone pruning, checkpoint-aware object-store GC."""

import numpy as np
import pytest

from repro.core import ManuConfig, ManuSystem
from repro.kernels import ops


@pytest.fixture
def system():
    return ManuSystem(
        ManuConfig(num_query_nodes=2, seal_rows=200, slice_rows=64, num_shards=2)
    )


def ingest(coll, rng, n, dim, batch=200):
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    for lo in range(0, n, batch):
        coll.insert({"vector": vecs[lo : lo + batch]})
    return vecs


def live_pks(res):
    return {int(pk) for pk in res.pks.ravel().tolist() if pk >= 0}


def test_end_to_end_compaction_demo(system, rng):
    """The acceptance scenario: delete >=30%, compact, prune, GC, re-delete."""
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 800, 8)
    coll.flush()
    sources = system.data_coord.sealed_segments("c")
    assert len(sources) >= 4

    victims = rng.choice(800, 320, replace=False)  # 40% tombstones
    coll.delete(victims)
    q = rng.standard_normal((3, 8)).astype(np.float32)
    after_delete = coll.search(q, limit=10, staleness_ms=0.0)
    assert not set(victims.tolist()) & live_pks(after_delete)
    assert all(
        len(qn.delta_deletes.get("c", {})) > 0 for qn in system.query_nodes.values()
    )

    epoch_before = system.meta.segment_map().epoch("c")
    report = coll.compact()
    assert report["tasks"] >= 1
    assert report["rows_purged"] == 320
    assert system.meta.segment_map().epoch("c") > epoch_before
    # segment identity swapped: no source survives in the live mapping
    live_map = set(system.meta.segment_map().live("c"))
    assert not live_map & set(sources)
    assert set(system.data_coord.sealed_segments("c")) == live_map

    # results unchanged through the swap
    post = coll.search(q, limit=10, staleness_ms=0.0)
    np.testing.assert_array_equal(
        np.sort(post.pks, 1), np.sort(after_delete.pks, 1)
    )

    # a post-compaction delete leaves only its own tombstones after GC
    late_victims = [pk for pk in range(800) if pk not in set(victims.tolist())][:5]
    coll.delete(np.asarray(late_victims))

    deleted_before_gc = system.store.bytes_deleted
    gc_report = coll.gc()
    assert gc_report["bytes"] > 0
    assert system.store.bytes_deleted - deleted_before_gc == gc_report["bytes"]
    assert system.store.delete_count >= len(sources)
    for sid in sources:  # old binlogs actually reclaimed
        assert not system.store.exists(f"binlog/c/{sid}/meta")
    for qn in system.query_nodes.values():
        dd = qn.delta_deletes.get("c", {})
        assert set(dd) <= set(late_victims)  # only post-compaction tombstones

    final = coll.search(q, limit=10, staleness_ms=0.0)
    assert not set(late_victims) & live_pks(final)
    assert not set(victims.tolist()) & live_pks(final)


def test_pinned_query_bit_identical_through_swap(system, rng):
    """MVCC: a query pinned before the compaction sees bit-for-bit the same
    results after the hot-swap (the retired versions keep serving it)."""
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 600, 8)
    coll.flush()
    coll.delete(rng.choice(600, 240, replace=False))
    q = rng.standard_normal((4, 8)).astype(np.float32)
    pinned = coll.search(q, limit=8, staleness_ms=0.0)

    report = coll.compact()
    assert report["tasks"] >= 1
    replay = coll.search(q, limit=8, time_travel_ts=pinned.query_ts)
    np.testing.assert_array_equal(pinned.pks, replay.pks)
    np.testing.assert_array_equal(pinned.scores, replay.scores)


def test_search_during_compaction_no_dups_no_misses(system, rng):
    """Strong searches issued between every scheduling round of an in-flight
    compaction return the exact same pk set, with no duplicates."""
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 600, 8)
    coll.flush()
    coll.delete(rng.choice(600, 200, replace=False))
    q = rng.standard_normal((2, 8)).astype(np.float32)
    baseline = coll.search(q, limit=10, staleness_ms=0.0)

    tasks = system.compaction_coord.plan("c")
    assert tasks
    for _ in range(200):
        res = coll.search(q, limit=10, staleness_ms=0.0)
        np.testing.assert_array_equal(
            np.sort(res.pks, 1), np.sort(baseline.pks, 1)
        )
        for r in range(len(q)):
            live = res.pks[r][res.pks[r] >= 0]
            assert len(set(live.tolist())) == len(live)
        if not system.compaction_coord.pending:
            break
        system.pump()
    assert not system.compaction_coord.pending


def test_small_segment_merge_up_to_seal_size(system, rng):
    """Sub-seal_size segments merge into one, preserving rows and results."""
    coll = system.create_collection("c", dim=8)
    for _ in range(3):
        ingest(coll, rng, 60, 8)
        coll.flush()
    before = system.data_coord.sealed_segments("c")
    assert len(before) >= 4  # fragmented: 2 shards x 3 flushes
    q = rng.standard_normal((2, 8)).astype(np.float32)
    pre = coll.search(q, limit=10, staleness_ms=0.0)

    report = coll.compact()
    assert report["tasks"] >= 1
    after = system.data_coord.sealed_segments("c")
    assert len(after) < len(before)
    assert sum(system.data_coord._sealed_rows.values()) == 180
    post = coll.search(q, limit=10, staleness_ms=0.0)
    np.testing.assert_array_equal(np.sort(pre.pks, 1), np.sort(post.pks, 1))


def test_time_travel_checkpoint_survives_gc(system, rng):
    """GC never reclaims binlogs referenced by a checkpoint; restore works."""
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 600, 8)
    coll.flush()
    system.checkpoint_collection("c")
    mark = system.tso.last_issued()
    protected = system.data_coord.sealed_segments("c")

    coll.delete(rng.choice(600, 240, replace=False))
    coll.compact()
    gc_report = coll.gc()
    assert gc_report["protected"] == len(protected)
    assert gc_report["objects"] == 0
    for sid in protected:
        assert system.store.exists(f"binlog/c/{sid}/meta")

    restored = system.restore_collection("c", mark)
    assert restored.num_rows() == 600
    q = rng.standard_normal((2, 8)).astype(np.float32)
    _s, p = restored.search(q, 3)
    assert (p >= 0).all()


def test_index_rebuilt_on_compacted_segment(system, rng):
    """The index coordinator re-triggers builds for rewrites; query nodes
    load them and search stays exact."""
    coll = system.create_collection("c", dim=8)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 4, "nprobe": 4})
    vecs = ingest(coll, rng, 600, 8)
    coll.flush()
    coll.delete(np.arange(240))
    report = coll.compact()
    assert report["tasks"] >= 1
    new_live = system.meta.segment_map().live("c")
    for sid in new_live:
        assert system.meta.get(f"index/c/{sid}/vector") is not None
    held = {
        sid: handle
        for qn in system.query_nodes.values()
        for (c, sid), handle in qn.sealed.items()
        if c == "c" and handle.retired_at_ts is None
    }
    assert set(held) == set(new_live)
    assert all(h.index is not None for h in held.values())

    q = rng.standard_normal((2, 8)).astype(np.float32)
    res = coll.search(q, limit=5, staleness_ms=0.0)
    keep = vecs[240:]
    d = (
        np.sum(q**2, 1, keepdims=True)
        - 2 * q @ keep.T
        + np.sum(keep**2, 1)
    )
    gt = np.argsort(d, axis=1)[:, :5] + 240
    hits = sum(
        len(set(res.pks[r].tolist()) & set(gt[r].tolist())) for r in range(2)
    )
    assert hits / 10 == 1.0  # nprobe == nlist: exhaustive => exact


def test_concurrent_compaction_nodes_cas_claim(rng):
    """Two compaction nodes never execute the same task twice."""
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, num_compaction_nodes=2, seal_rows=200,
                   slice_rows=64)
    )
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 800, 8)
    coll.flush()
    coll.delete(rng.choice(800, 320, replace=False))
    report = coll.compact()
    done = sum(cn.compactions_completed for cn in system.compaction_nodes)
    assert done == report["tasks"] == system.compaction_coord.compactions_completed


def test_isin_sorted_matches_np_isin(rng):
    """The per-request delta-mask probe is equivalent to np.isin."""
    for n_hay, n_val in ((0, 10), (7, 0), (1, 5), (100, 1000), (1000, 100)):
        hay = np.unique(rng.integers(0, 5000, n_hay))
        vals = rng.integers(0, 5000, n_val)
        np.testing.assert_array_equal(
            ops.isin_sorted(vals, hay), np.isin(vals, hay)
        )


def test_object_store_delete_accounting(tmp_path):
    from repro.core.object_store import FileObjectStore, MemoryObjectStore

    for store in (MemoryObjectStore(), FileObjectStore(str(tmp_path))):
        store.put("a", b"x" * 100)
        store.put("b", b"y" * 50)
        assert store.delete("a") is True
        assert store.delete("a") is False  # only real removals count
        assert store.delete("missing") is False
        assert store.delete_count == 1
        assert store.bytes_deleted == 100


def test_all_rows_dead_leaves_no_phantom_segment(system, rng):
    """A rewrite whose rows are all tombstoned emits no target at all."""
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 400, 8)
    coll.flush()
    coll.delete(np.arange(400))
    report = coll.compact()
    assert report["tasks"] >= 1 and report["rows_purged"] == 400
    assert system.meta.segment_map().live("c") == []
    assert system.data_coord.sealed_segments("c") == []
    # coordinator's own tombstone view is pruned with the fold
    assert not system.compaction_coord.tombstones.get("c")
    # per-cycle accounting: a second cycle purges nothing new
    assert coll.compact()["rows_purged"] == 0
    coll.gc()
    assert not list(system.store.list("binlog/c/"))


def test_gc_is_scoped_per_collection(system, rng):
    """gc('a') must not release collection b's retired versions."""
    a = system.create_collection("a", dim=8)
    b = system.create_collection("b", dim=8)
    for coll in (a, b):
        ingest(coll, rng, 400, 8)
        coll.flush()
        coll.delete(rng.choice(400, 160, replace=False))
        coll.compact()

    def retired(name):
        return [
            key
            for qn in system.query_nodes.values()
            for key, h in qn.sealed.items()
            if key[0] == name and h.retired_at_ts is not None
        ]

    assert retired("a") and retired("b")
    report = a.gc()
    assert all(c == "a" for c, _sid in report["segments"])
    assert not retired("a") and retired("b")
    assert list(system.store.list("binlog/b/"))  # b untouched until its gc
    b.gc()
    assert not retired("b")


def test_failover_preserves_mvcc_gate_of_rewrites(system, rng):
    """A compacted segment reloaded through failover keeps its
    visible_from_ts gate (a reload must not reset the MVCC window)."""
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 400, 8)
    coll.flush()
    coll.delete(rng.choice(400, 160, replace=False))
    coll.compact()
    q = rng.standard_normal((2, 8)).astype(np.float32)
    baseline = coll.search(q, limit=8, staleness_ms=0.0)

    live = system.meta.segment_map().live("c")
    victim = system.query_coord.assignment[("c", live[0])]
    system.kill_query_node(victim)
    system.recover_failures()

    gates = {
        sid: h.visible_from_ts
        for qn in system.query_nodes.values()
        if qn.alive
        for (c, sid), h in qn.sealed.items()
        if c == "c" and sid in live
    }
    assert set(gates) == set(live)
    assert all(ts > 0 for ts in gates.values())
    after = coll.search(q, limit=8, staleness_ms=0.0)
    np.testing.assert_array_equal(
        np.sort(baseline.pks, 1), np.sort(after.pks, 1)
    )


def test_retired_handle_serves_until_horizon_then_drops(system, rng):
    """Retired segment versions are released only by the retention horizon."""
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 400, 8)
    coll.flush()
    coll.delete(rng.choice(400, 160, replace=False))
    coll.compact()
    retired = [
        (key, h)
        for qn in system.query_nodes.values()
        for key, h in qn.sealed.items()
        if h.retired_at_ts is not None
    ]
    assert retired  # old versions still held for pinned readers
    coll.gc()
    for qn in system.query_nodes.values():
        assert all(h.retired_at_ts is None for h in qn.sealed.values())
