"""TSO/HLC, log broker, meta store, object store — unit + property tests."""

import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.log import (
    COORD_CHANNEL,
    EntryType,
    LogBroker,
    LogEntry,
    Subscription,
    dml_channel,
    shard_of_pk,
)
from repro.core.meta_store import MetaStore
from repro.core.object_store import FileObjectStore, MemoryObjectStore
from repro.core.timestamp import (
    TSO,
    ManualClock,
    Timestamp,
    logical_of,
    pack,
    physical_of,
)


# ------------------------------------------------------------------ HLC/TSO
@given(st.integers(0, 2**40), st.integers(0, 2**18 - 1))
def test_hlc_pack_roundtrip(phys, logical):
    ts = pack(phys, logical)
    assert physical_of(ts) == phys
    assert logical_of(ts) == logical
    assert Timestamp.unpack(ts).packed() == ts


@given(st.lists(st.integers(0, 5), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_tso_strictly_increasing(advances):
    """Property: regardless of clock behaviour, TSO output is strictly
    monotone (the total-order MVCC depends on)."""
    clock = ManualClock(1000)
    tso = TSO(clock)
    last = 0
    for adv in advances:
        clock.advance(adv)
        ts = tso.next()
        assert ts > last
        last = ts


def test_tso_physical_tracks_clock():
    clock = ManualClock(5_000)
    tso = TSO(clock)
    assert physical_of(tso.next()) == 5_000
    clock.advance(123)
    assert physical_of(tso.next()) == 5_123


def test_tso_thread_safety():
    tso = TSO(ManualClock(0))
    out: list[int] = []
    lock = threading.Lock()

    def worker():
        for _ in range(500):
            ts = tso.next()
            with lock:
                out.append(ts)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(set(out)) == len(out), "duplicate timestamps issued"


# ------------------------------------------------------------------- broker
def test_broker_ordering_and_positions():
    broker = LogBroker()
    broker.create_channel("c")
    for i in range(5):
        pos = broker.publish("c", LogEntry(ts=i + 1, type=EntryType.COORD, payload={"i": i}))
        assert pos == i
    entries = broker.read("c", 2)
    assert [e.payload["i"] for e in entries] == [2, 3, 4]
    with pytest.raises(ValueError):
        broker.publish("c", LogEntry(ts=1, type=EntryType.COORD, payload={}))  # out of order


def test_subscription_poll_and_seek():
    broker = LogBroker()
    broker.create_channel("c")
    sub = Subscription(broker, "c")
    for i in range(4):
        broker.publish("c", LogEntry(ts=i + 1, type=EntryType.COORD, payload={"i": i}))
    got = sub.poll()
    assert [e.payload["i"] for e in got] == [0, 1, 2, 3]
    assert sub.poll() == []
    sub.seek(1)
    assert [e.payload["i"] for e in sub.poll()] == [1, 2, 3]


def test_time_ticks_update_watermark():
    broker = LogBroker()
    broker.create_channel("c")
    sub = Subscription(broker, "c")
    broker.publish("c", LogEntry(ts=10, type=EntryType.TIME_TICK, payload={}))
    broker.publish("c", LogEntry(ts=20, type=EntryType.INSERT, payload={}))
    broker.publish("c", LogEntry(ts=30, type=EntryType.TIME_TICK, payload={}))
    sub.poll()
    assert sub.last_tick_seen == 30
    assert broker.last_tick("c") == 30


def test_truncate_before():
    broker = LogBroker()
    broker.create_channel("c")
    for i in range(10):
        broker.publish("c", LogEntry(ts=(i + 1) * 10, type=EntryType.COORD, payload={}))
    dropped = broker.truncate_before("c", 55)
    assert dropped == 5


@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=50), st.integers(1, 8))
def test_shard_of_pk_stable_and_in_range(pks, shards):
    for pk in pks:
        s = shard_of_pk(pk, shards)
        assert 0 <= s < shards
        assert s == shard_of_pk(pk, shards)


# --------------------------------------------------------------- meta store
def test_meta_cas_and_watch():
    ms = MetaStore()
    events = []
    ms.watch("a/", lambda k, v: events.append((k, v)))
    rev = ms.put("a/x", {"v": 1})
    assert ms.cas("a/x", rev, {"v": 2})
    assert not ms.cas("a/x", rev, {"v": 3})  # stale rev
    assert ms.get("a/x") == {"v": 2}
    assert not ms.cas("a/new", 5, {})  # create requires expected None
    assert ms.cas("a/new", None, {"v": 0})
    ms.delete("a/x")
    keys = [k for k, _ in events]
    assert keys == ["a/x", "a/x", "a/new", "a/x"]
    assert events[-1][1] is None  # delete notification


def test_meta_lease_expiry():
    clock = ManualClock(0)
    ms = MetaStore(clock)
    lease = ms.grant_lease(ttl_ms=100)
    ms.put("node/1", {"alive": True}, lease_id=lease)
    assert ms.get("node/1") is not None
    clock.advance(50)
    ms.keepalive(lease)
    clock.advance(80)
    assert ms.expire_now() == []  # keepalive extended it
    clock.advance(200)
    assert "node/1" in ms.expire_now()
    assert ms.get("node/1") is None


def test_meta_isolation():
    ms = MetaStore()
    value = {"nested": [1, 2]}
    ms.put("k", value)
    value["nested"].append(3)  # caller mutation must not leak in
    assert ms.get("k") == {"nested": [1, 2]}
    got = ms.get("k")
    got["nested"].append(4)  # reader mutation must not leak back
    assert ms.get("k") == {"nested": [1, 2]}


# -------------------------------------------------------------- object store
@pytest.mark.parametrize("factory", [MemoryObjectStore, None])
def test_object_store_semantics(tmp_path, factory):
    store = factory() if factory else FileObjectStore(str(tmp_path / "os"))
    meta = store.put("a/b/c", b"hello")
    assert meta.size == 5
    assert store.get("a/b/c") == b"hello"
    assert store.exists("a/b/c")
    store.put("a/b/d", b"x")
    keys = [m.key for m in store.list("a/b/")]
    assert keys == ["a/b/c", "a/b/d"]
    store.delete("a/b/c")
    assert not store.exists("a/b/c")
    with pytest.raises(KeyError):
        store.get("a/b/c")
