"""The typed mutation API: upsert atomicity vs the delete+insert oracle,
MutationResult watermarks feeding SESSION reads, partition placement and
pruning, legacy facade equivalence, string-pk shard hashing, and the
validate_rows / empty-delete satellites."""

import numpy as np
import pytest

from repro.core import (
    ConsistencyLevel,
    DeleteRequest,
    FieldSchema,
    FieldType,
    InsertRequest,
    ManuConfig,
    ManuSystem,
    Metric,
    Schema,
    SearchRequest,
    UpsertRequest,
)
from repro.core.collection import validate_rows
from repro.core.log import dml_channel, shard_of_pk, shards_of_pks
from repro.core.segment import DEFAULT_PARTITION
from repro.kernels import ops


def make_system(**kw):
    cfg = dict(num_query_nodes=2, seal_rows=200, slice_rows=64, num_shards=2)
    cfg.update(kw)
    return ManuSystem(ManuConfig(**cfg))


@pytest.fixture
def system():
    return make_system()


def live(res):
    return set(res.pks[res.pks >= 0].ravel().tolist())


def brute_l2(base, queries, k):
    d = np.sum(queries**2, 1, keepdims=True) - 2 * queries @ base.T + np.sum(base**2, 1)
    return np.argsort(d, axis=1)[:, :k]


# ---------------------------------------------------------------------------
# Upsert: atomicity + delete+insert equivalence oracle
# ---------------------------------------------------------------------------


def seeded_pair(rng_seed=3, n=500, dim=8):
    """Two identically seeded systems with the same ingested collection."""
    rng = np.random.default_rng(rng_seed)
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    systems = []
    for _ in range(2):
        s = make_system()
        c = s.create_collection("c", dim=dim)
        c.insert({"vector": vecs})
        c.flush()
        systems.append((s, c))
    return systems, vecs, rng


def test_upsert_equals_delete_plus_insert_oracle():
    (sa, ca), (sb, cb) = seeded_pair()[0]
    rng = np.random.default_rng(11)
    victims = np.arange(0, 40, dtype=np.int64)
    newv = (rng.standard_normal((40, 8)) * 3).astype(np.float32)

    res = ca.upsert({"pk": victims, "vector": newv})
    assert res.op == "upsert" and res.ack_rows == 40
    cb.delete(victims)
    cb.insert({"pk": victims, "vector": newv})

    q = rng.standard_normal((4, 8)).astype(np.float32)
    ra = ca.search(q, limit=10, staleness_ms=0.0)
    rb = cb.search(q, limit=10, staleness_ms=0.0)
    np.testing.assert_array_equal(ra.pks, rb.pks)
    np.testing.assert_allclose(ra.scores, rb.scores, rtol=1e-6)


def test_upsert_atomic_at_one_timestamp():
    """Time-travel at watermark_ts - 1 sees only the OLD rows, at
    watermark_ts only the NEW rows — bit-for-bit vs the pinned reads."""
    (sa, ca), _ = seeded_pair()[0]
    rng = np.random.default_rng(5)
    q = rng.standard_normal((3, 8)).astype(np.float32)
    pre = ca.search(q, limit=8, staleness_ms=0.0)

    victims = pre.pks[:, :3].ravel()
    victims = np.unique(victims[victims >= 0])
    newv = (rng.standard_normal((len(victims), 8)) * 50).astype(np.float32)
    res = ca.upsert({"pk": victims, "vector": newv})
    wm = res.watermark_ts

    at_minus = ca.search(q, limit=8, time_travel_ts=wm - 1)
    np.testing.assert_array_equal(at_minus.pks, pre.pks)
    np.testing.assert_allclose(at_minus.scores, pre.scores, rtol=1e-6)

    at_wm = ca.search(q, limit=8, time_travel_ts=wm)
    post = ca.search(q, limit=8, staleness_ms=0.0)
    np.testing.assert_array_equal(at_wm.pks, post.pks)
    # old versions invisible at the watermark, new versions visible
    assert not set(victims.tolist()) & live(at_wm) or (
        # upserted pks may still rank: but then their score must be the NEW
        # vector's distance, which post-search agrees with bit-for-bit
        np.array_equal(at_wm.scores, post.scores)
    )


def test_upsert_without_pk_degrades_to_insert(system, rng):
    coll = system.create_collection("c", dim=8)
    res = coll.upsert({"vector": rng.standard_normal((30, 8)).astype(np.float32)})
    assert res.op == "insert"
    assert len(res.pks) == 30
    assert coll.num_entities() == 30


def test_repeated_upsert_chain_visibility(system, rng):
    """pk upserted twice: each pinned read sees exactly one version."""
    coll = system.create_collection("c", dim=4)
    v0 = np.full((1, 4), 1.0, np.float32)
    v1 = np.full((1, 4), 10.0, np.float32)
    v2 = np.full((1, 4), 100.0, np.float32)
    coll.insert({"pk": np.array([7]), "vector": v0})
    r1 = coll.upsert({"pk": np.array([7]), "vector": v1})
    r2 = coll.upsert({"pk": np.array([7]), "vector": v2})
    q = np.zeros((1, 4), np.float32)

    def score_at(ts):
        r = coll.search(q, limit=1, time_travel_ts=ts)
        assert r.pks[0, 0] == 7
        return float(r.scores[0, 0])

    # L2 distance to origin identifies which version answered
    assert score_at(r1.watermark_ts - 1) == pytest.approx(4 * 1.0)
    assert score_at(r1.watermark_ts) == pytest.approx(4 * 100.0)
    assert score_at(r2.watermark_ts - 1) == pytest.approx(4 * 100.0)
    assert score_at(r2.watermark_ts) == pytest.approx(4 * 10000.0)
    # exactly one visible version at any pinned ts (no duplicate pk rows)
    r = coll.search(q, limit=3, staleness_ms=0.0)
    assert (r.pks[0] == 7).sum() == 1


def test_upsert_survives_compaction(system, rng):
    """Compaction rewrites are row-version aware: the upserted NEW rows
    survive the fold even though their pks are tombstoned."""
    coll = system.create_collection("c", dim=8)
    vecs = rng.standard_normal((600, 8)).astype(np.float32)
    coll.insert({"vector": vecs})
    coll.flush()
    victims = np.arange(0, 240, dtype=np.int64)
    newv = (rng.standard_normal((240, 8)) * 2).astype(np.float32)
    coll.upsert({"pk": victims, "vector": newv})
    coll.flush()

    q = rng.standard_normal((3, 8)).astype(np.float32)
    before = coll.search(q, limit=10, staleness_ms=0.0)
    report = coll.compact()
    assert report["tasks"] >= 1
    after = coll.search(q, limit=10, staleness_ms=0.0)
    np.testing.assert_array_equal(before.pks, after.pks)
    np.testing.assert_allclose(before.scores, after.scores, rtol=1e-6)
    # only the OLD versions were purged
    assert report["rows_purged"] == 240


# ---------------------------------------------------------------------------
# MutationResult watermarks -> SESSION reads
# ---------------------------------------------------------------------------


def test_watermark_feeds_session_read(rng):
    # ticks ~never fire on their own: only the session wait pinned at the
    # mutation's watermark can make the fresh rows visible
    system = make_system(num_query_nodes=1, seal_rows=10_000, tick_interval_ms=1e12)
    coll = system.create_collection("c", dim=4)
    res = coll.mutate(
        InsertRequest({"vector": rng.standard_normal((40, 4)).astype(np.float32)})
    )
    q = rng.standard_normal((1, 4)).astype(np.float32)
    r = coll.search(res.session_request(q, k=5))  # MutationResult helper
    assert (r.pks[0] >= 0).sum() == 5


def test_session_helper_equals_manual_request(system, rng):
    coll = system.create_collection("c", dim=4)
    res = coll.mutate(
        InsertRequest({"vector": rng.standard_normal((40, 4)).astype(np.float32)})
    )
    q = rng.standard_normal((1, 4)).astype(np.float32)
    manual = coll.search(
        SearchRequest.single(
            q, k=5, consistency=ConsistencyLevel.SESSION,
            session_ts=res.watermark_ts,
        )
    )
    helper = coll.search(res.session_request(q, k=5))
    np.testing.assert_array_equal(manual.pks, helper.pks)
    assert (helper.pks[0] >= 0).sum() == 5


def test_mutation_result_shape(system, rng):
    coll = system.create_collection("c", dim=8)
    res = coll.mutate(
        InsertRequest({"vector": rng.standard_normal((50, 8)).astype(np.float32)})
    )
    assert res.op == "insert"
    assert res.row_count == res.ack_rows == 50
    assert len(res.pks) == 50
    assert res.shard_lsns and all(
        lsn == res.watermark_ts for lsn in res.shard_lsns.values()
    )  # one LSN per request: row-level ACID
    d = coll.mutate(DeleteRequest(res.pks[:7]))
    assert d.op == "delete" and d.ack_rows == 7
    assert d.watermark_ts >= res.watermark_ts


# ---------------------------------------------------------------------------
# Partitions: placement + pruning
# ---------------------------------------------------------------------------


def partitioned_pair(rng, n=600, dim=8, parts=("hot", "cold", "warm")):
    """One partitioned and one unpartitioned collection with identical
    rows; returns (system, part_coll, flat_coll, vectors, part_of_pk)."""
    system = make_system()
    pc = system.create_collection("p", dim=dim)
    fc = system.create_collection("f", dim=dim)
    for p in parts:
        pc.create_partition(p)
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    part_of = {}
    step = n // len(parts)
    for i, p in enumerate(parts):
        lo, hi = i * step, (i + 1) * step if i < len(parts) - 1 else n
        pks = np.arange(lo, hi, dtype=np.int64)
        pc.insert(InsertRequest({"pk": pks, "vector": vecs[lo:hi]}, partition=p))
        fc.insert({"pk": pks, "vector": vecs[lo:hi]})
        for pk in pks.tolist():
            part_of[pk] = p
    pc.flush()
    fc.flush()
    return system, pc, fc, vecs, part_of


def test_partition_pruning_matches_unpartitioned_oracle(rng):
    system, pc, fc, vecs, part_of = partitioned_pair(rng)
    q = rng.standard_normal((4, 8)).astype(np.float32)
    hot_pks = {pk for pk, p in part_of.items() if p == "hot"}

    res = pc.search(q, limit=10, staleness_ms=0.0, partition_names=("hot",))
    assert live(res) <= hot_pks
    # oracle: exact brute force over only the partition's rows
    idx = np.array(sorted(hot_pks))
    gt = idx[brute_l2(vecs[idx], q, 10)]
    np.testing.assert_array_equal(res.pks, gt)

    # multi-partition request unions the partitions
    res2 = pc.search(q, limit=10, staleness_ms=0.0,
                     partition_names=("hot", "cold"))
    hc = np.array(sorted({pk for pk, p in part_of.items() if p in ("hot", "cold")}))
    np.testing.assert_array_equal(res2.pks, hc[brute_l2(vecs[hc], q, 10)])

    # no partition filter == the unpartitioned twin, bit for bit
    r_all = pc.search(q, limit=10, staleness_ms=0.0)
    r_flat = fc.search(q, limit=10, staleness_ms=0.0)
    np.testing.assert_array_equal(r_all.pks, r_flat.pks)


def test_planner_visits_only_matching_segments(rng):
    system, pc, fc, vecs, part_of = partitioned_pair(rng)
    ts = system.tso.last_issued()
    hot_sids = set()
    for sid in system.data_coord.sealed_segments("p"):
        if system.data_coord.segment_partition("p", sid) == "hot":
            hot_sids.add(sid)
    assert hot_sids
    visited_pruned, visited_full = set(), set()
    for qn in system.query_nodes.values():
        for u in qn.plan_search("p", ts, partitions=("hot",)).units():
            visited_pruned.add(u.segment_id)
        for u in qn.plan_search("p", ts).units():
            visited_full.add(u.segment_id)
    assert visited_pruned, "pruned plan must still cover the partition"
    assert visited_pruned <= hot_sids  # provably only matching segments
    assert visited_pruned < visited_full


def test_partition_search_during_compaction(rng):
    """Partition-scoped reads stay exact while a partitioned collection's
    segments are being compacted (grouping never crosses partitions)."""
    system, pc, fc, vecs, part_of = partitioned_pair(rng)
    victims = np.array(sorted({pk for pk, p in part_of.items() if p == "hot"}))[:150]
    pc.delete(victims)
    fc.delete(victims)
    q = rng.standard_normal((2, 8)).astype(np.float32)
    baseline = pc.search(q, limit=10, staleness_ms=0.0, partition_names=("hot",))
    assert not set(victims.tolist()) & live(baseline)

    tasks = system.compaction_coord.plan("p")
    assert tasks
    # tasks never mix partitions
    for t in tasks:
        parts = {
            system.data_coord.segment_partition("p", sid) for sid in t["sources"]
        }
        assert len(parts) == 1 and parts == {t["partition"]}
    # search between every scheduling round of the in-flight compaction
    for _ in range(40):
        mid = pc.search(q, limit=10, staleness_ms=0.0, partition_names=("hot",))
        np.testing.assert_array_equal(mid.pks, baseline.pks)
        if not system.pump():
            break
    after = pc.search(q, limit=10, staleness_ms=0.0, partition_names=("hot",))
    np.testing.assert_array_equal(after.pks, baseline.pks)
    # rewritten segments keep their partition tag
    for sid in system.data_coord.sealed_segments("p"):
        assert system.data_coord.segment_partition("p", sid) in (
            "hot", "cold", "warm", DEFAULT_PARTITION,
        )


def test_drop_partition_removes_rows_and_unknown_partition_rejected(rng):
    system, pc, fc, vecs, part_of = partitioned_pair(rng)
    cold = {pk for pk, p in part_of.items() if p == "cold"}
    hot = {pk for pk, p in part_of.items() if p == "hot"}
    # tombstones in both partitions: the cold ones become unfoldable once
    # the partition is gone and must be pruned at the retention horizon
    cold_victims = sorted(cold)[:5]
    hot_victims = sorted(hot)[:5]
    pc.delete(np.asarray(cold_victims + hot_victims))
    q = rng.standard_normal((2, 8)).astype(np.float32)
    report = pc.drop_partition("cold")
    assert report["segments_dropped"] >= 1
    after = pc.search(q, limit=20, staleness_ms=0.0)
    assert not cold & live(after)
    assert "cold" not in pc.partitions()
    # unknown partition: writes and reads reject early
    with pytest.raises(KeyError):
        pc.insert(InsertRequest({"vector": vecs[:5]}, partition="cold"))
    with pytest.raises(ValueError):
        pc.search(q, limit=5, partition_names=("cold",))
    with pytest.raises(ValueError):
        pc.drop_partition(DEFAULT_PARTITION)
    # dropped binlogs become reclaimable garbage
    rep = pc.gc()
    assert rep["segments"]
    # the gc's retention advance pruned tombstones of pks that lived only
    # in the dropped partition; tombstones still covering live rows stay
    kept = set()
    for qn in system.query_nodes.values():
        dd = qn.delta_deletes.get("p", {})
        assert not set(dd) & cold
        kept |= set(dd)
    # tombstones still covering live (hot) rows survive across the cluster
    # (each node holds the ones of its subscribed shard channels)
    assert set(hot_victims) <= kept
    assert not set(system.compaction_coord.tombstones.get("p", {})) & cold


def test_typed_request_rejects_stray_partition_kwarg(system, rng):
    coll = system.create_collection("c", dim=4)
    coll.create_partition("hot")
    rows = {"vector": rng.standard_normal((3, 4)).astype(np.float32)}
    with pytest.raises(ValueError, match="inside the InsertRequest"):
        coll.insert(InsertRequest(rows), partition="hot")
    with pytest.raises(ValueError, match="inside the UpsertRequest"):
        coll.upsert(UpsertRequest(rows), partition="hot")


def test_session_request_resolves_custom_vector_field(rng):
    """MutationResult.session_request works on collections whose primary
    vector field is not named 'vector'."""
    schema = Schema(
        (
            FieldSchema("pk", FieldType.INT, is_primary=True),
            FieldSchema("emb", FieldType.VECTOR, dim=4),
        )
    )
    system = make_system()
    coll = system.create_collection("e", dim=4, schema=schema)
    res = coll.upsert(
        {"pk": np.arange(20), "emb": rng.standard_normal((20, 4)).astype(np.float32)}
    )
    q = rng.standard_normal((1, 4)).astype(np.float32)
    r = coll.search(res.session_request(q, k=5))
    assert (r.pks[0] >= 0).sum() == 5


# ---------------------------------------------------------------------------
# Legacy facade back-compat
# ---------------------------------------------------------------------------


def test_legacy_facades_run_through_pipeline(rng):
    """coll.insert(dict) / coll.delete(array) return bare LSNs and produce
    bit-identical state to the typed requests."""
    vecs = rng.standard_normal((400, 8)).astype(np.float32)
    sa, sb = make_system(), make_system()
    ca = sa.create_collection("c", dim=8)
    cb = sb.create_collection("c", dim=8)

    lsn = ca.insert({"vector": vecs})  # legacy: bare int LSN
    assert isinstance(lsn, (int, np.integer))
    res = cb.mutate(InsertRequest({"vector": vecs}))
    assert res.watermark_ts == lsn  # identical ManualClock schedules

    dl = ca.delete(np.arange(10))
    assert isinstance(dl, (int, np.integer))
    cb.mutate(DeleteRequest(np.arange(10)))

    q = rng.standard_normal((3, 8)).astype(np.float32)
    ra = ca.search(q, limit=8, staleness_ms=0.0)
    rb = cb.search(q, limit=8, staleness_ms=0.0)
    np.testing.assert_array_equal(ra.pks, rb.pks)
    np.testing.assert_allclose(ra.scores, rb.scores)
    # proxy/logger facades answer the old tuple/int shapes
    lsn2, n2 = sa.proxy.insert(ca.info, {"vector": vecs[:5]})
    assert n2 == 5 and lsn2 > lsn


def test_session_read_your_writes_through_legacy_facade(rng):
    system = make_system(num_query_nodes=1, seal_rows=10_000, tick_interval_ms=1e12)
    coll = system.create_collection("c", dim=4)
    coll.insert({"vector": rng.standard_normal((30, 4)).astype(np.float32)})
    q = rng.standard_normal((1, 4)).astype(np.float32)
    res = coll.search(q, limit=5, read_your_writes=True)
    assert (res.pks[0] >= 0).sum() == 5


# ---------------------------------------------------------------------------
# String primary keys: vectorized shard hashing
# ---------------------------------------------------------------------------


def test_string_pk_vectorized_hash_matches_scalar(rng):
    keys = np.array(
        ["user-%d" % i for i in range(50)]
        + ["", "a", "Ω-unicode-Ψ", "日本語キー", "x" * 40]
    )
    for shards in (1, 2, 3, 7):
        vec = shards_of_pks(keys, shards)
        ref = np.array([shard_of_pk(k, shards) for k in keys.tolist()])
        np.testing.assert_array_equal(vec, ref)
    ints = rng.integers(0, 1 << 40, 200)
    np.testing.assert_array_equal(
        shards_of_pks(ints, 5), np.array([shard_of_pk(int(p), 5) for p in ints])
    )


def test_string_pk_rows_route_by_hash(rng):
    schema = Schema(
        (
            FieldSchema("pk", FieldType.STRING, is_primary=True),
            FieldSchema("vector", FieldType.VECTOR, dim=4),
        )
    )
    system = make_system(num_shards=2)
    coll = system.create_collection("s", dim=4, schema=schema)
    pks = np.array([f"doc-{i}" for i in range(100)])
    vecs = rng.standard_normal((100, 4)).astype(np.float32)
    res = coll.mutate(InsertRequest({"pk": pks, "vector": vecs}))
    assert res.row_count == 100 and set(res.shard_lsns) == {0, 1}
    # every WAL record landed on the channel its pks hash to, rows intact
    seen = []
    for shard in range(2):
        for e in system.broker.read(dml_channel("s", shard), 0):
            if "pk" in e.payload:
                got = e.payload["pk"]
                np.testing.assert_array_equal(
                    shards_of_pks(got, 2), np.full(len(got), shard)
                )
                seen.extend(got.tolist())
    assert sorted(seen) == sorted(pks.tolist())
    assert coll.num_entities() == 100


# ---------------------------------------------------------------------------
# validate_rows satellite
# ---------------------------------------------------------------------------


def test_validate_rows_rejects_stray_and_empty(rng):
    schema = Schema.simple(4)
    with pytest.raises(ValueError, match="no fields"):
        validate_rows(schema, {})
    with pytest.raises(ValueError, match="prise"):
        validate_rows(
            schema,
            {"vector": np.zeros((2, 4), np.float32), "prise": np.zeros(2)},
        )
    # the error lists every stray key
    with pytest.raises(ValueError, match="bad_a.*bad_b"):
        validate_rows(
            schema,
            {
                "vector": np.zeros((2, 4), np.float32),
                "bad_b": np.zeros(2),
                "bad_a": np.zeros(2),
            },
        )
    system = make_system()
    coll = system.create_collection("c", dim=4)
    with pytest.raises(ValueError, match="vektor"):
        coll.insert({"vektor": rng.standard_normal((2, 4)).astype(np.float32)})


# ---------------------------------------------------------------------------
# Empty / no-match delete satellite
# ---------------------------------------------------------------------------


def test_empty_delete_is_noop_with_valid_watermark(system, rng):
    coll = system.create_collection("c", dim=4)
    coll.insert({"vector": rng.standard_normal((50, 4)).astype(np.float32)})
    entries_before = {
        ch: system.broker.end_position(ch) for ch in system.broker.channels("dml/")
    }
    res = coll.mutate(DeleteRequest(np.array([], dtype=np.int64)))
    assert res.ack_rows == 0 and res.shard_lsns == {}
    # nothing was published (ticks aside, no DELETE entries)
    for ch, before in entries_before.items():
        new = system.broker.read(ch, before)
        assert all(e.payload == {} for e in new)  # time-ticks only
    # the watermark is valid: a SESSION read pinned at it succeeds
    q = rng.standard_normal((1, 4)).astype(np.float32)
    r = coll.search(res.session_request(q, k=5))
    assert (r.pks[0] >= 0).sum() == 5


def test_no_match_delete_is_noop(system, rng):
    coll = system.create_collection("c", dim=4)
    coll.insert({"vector": rng.standard_normal((50, 4)).astype(np.float32)})
    res = coll.mutate(DeleteRequest(np.array([123_456, 999_999, -3])))
    assert res.ack_rows == 0 and res.shard_lsns == {}
    assert res.row_count == 3  # requested vs acknowledged
    # partial overlap still publishes only the real pks
    res2 = coll.mutate(DeleteRequest(np.array([0, 777_777])))
    assert res2.ack_rows == 1
    q = rng.standard_normal((1, 4)).astype(np.float32)
    r = coll.search(q, limit=50, staleness_ms=0.0)
    assert 0 not in live(r)


# ---------------------------------------------------------------------------
# Tombstone kernel units (the machinery under the upsert semantics)
# ---------------------------------------------------------------------------


def test_eff_tombstones_and_mask_match_naive(rng):
    for _ in range(20):
        n_pairs = int(rng.integers(1, 60))
        pks = rng.integers(0, 30, n_pairs)
        dts = rng.integers(1, 100, n_pairs).astype(np.int64)
        ts = int(rng.integers(0, 110))
        eff = ops.eff_tombstones(pks, dts, ts)
        seg_pks = rng.integers(0, 35, 50)
        seg_ts = rng.integers(0, 110, 50).astype(np.int64)
        if eff is None:
            killed = np.zeros(50, bool)
        else:
            killed = ops.tombstone_mask(seg_pks, seg_ts, eff[0], eff[1])
        # naive per-row oracle
        want = np.zeros(50, bool)
        for i in range(50):
            for p, d in zip(pks.tolist(), dts.tolist()):
                if p == seg_pks[i] and seg_ts[i] < d <= ts:
                    want[i] = True
        np.testing.assert_array_equal(killed, want)


def test_shard_split_grouping(rng):
    shards = rng.integers(0, 4, 200)
    order, offsets = ops.shard_split(shards, 4)
    for s in range(4):
        sel = order[offsets[s] : offsets[s + 1]]
        assert (shards[sel] == s).all()
        # stable: arrival order preserved within the shard
        assert (np.diff(sel) > 0).all() or len(sel) <= 1
    assert offsets[-1] == 200
