"""Equivalence tests for the fused search execution path.

``merge_topk`` (host vectorized + jnp ref + Pallas interpret) must match
the original per-row Python dedup merge bit-for-bit; the fused segmented
scan must match per-segment ``topk_scan`` up to gemm accumulation order;
and the node-level engine must reproduce the seed scan-then-merge
pipeline end to end.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.collection import Metric
from repro.core.consistency import GuaranteeTs
from repro.core.log import LogBroker
from repro.core.object_store import MemoryObjectStore
from repro.core.query_node import QueryNode, SealedHandle
from repro.core.segment import Segment
from repro.core.timestamp import INFINITE_STALENESS
from repro.kernels import ops, ref
from repro.kernels.merge_topk import merge_topk_pallas

# the pre-fusion per-row Python dedup merge: the semantic baseline
from benchmarks.common import python_dedup_merge as seed_merge


def random_pool(rng, nq, m, metric, pk_range=10):
    """Candidate pool with duplicate pks, -1 slots and non-finite scores."""
    s = rng.standard_normal((nq, m)).astype(np.float32)
    if metric == "l2":
        s = np.abs(s)
    p = rng.integers(-1, pk_range, (nq, m)).astype(np.int64)
    s[rng.random((nq, m)) < 0.10] = np.inf
    s[rng.random((nq, m)) < 0.05] = -np.inf
    s[rng.random((nq, m)) < 0.05] = np.nan
    # exact score ties to exercise stable tie-breaks
    ties = rng.random((nq, m)) < 0.1
    s[ties] = 1.25
    return s, p


@given(
    nq=st.integers(1, 8),
    m=st.integers(1, 48),
    k=st.integers(1, 24),
    seed=st.integers(0, 10_000),
    metric=st.one_of(st.just("l2"), st.just("ip")),
)
@settings(max_examples=60, deadline=None)
def test_merge_topk_matches_seed_python_merge(nq, m, k, seed, metric):
    rng = np.random.default_rng(seed)
    s, p = random_pool(rng, nq, m, metric)
    want_s, want_p = seed_merge(s, p, k, metric)
    got_s, got_p = ops.merge_topk(s, p, k, metric)
    np.testing.assert_array_equal(want_s, got_s)
    np.testing.assert_array_equal(want_p, got_p)


@given(seed=st.integers(0, 10_000), metric=st.one_of(st.just("l2"), st.just("ip")))
@settings(max_examples=20, deadline=None)
def test_merge_topk_ref_matches_seed(seed, metric):
    rng = np.random.default_rng(seed)
    s, p = random_pool(rng, 4, 32, metric)
    want_s, want_p = seed_merge(s, p, 10, metric)
    got_s, got_p = ref.merge_topk_ref(jnp.asarray(s), jnp.asarray(p), 10, metric)
    np.testing.assert_array_equal(want_s, np.asarray(got_s))
    np.testing.assert_array_equal(want_p, np.asarray(got_p))


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_merge_topk_pallas_interpret_matches_ref(metric):
    rng = np.random.default_rng(7)
    nq, m, k = 8, 128, 12
    s, p = random_pool(rng, nq, m, metric, pk_range=40)
    want_s, want_p = ref.merge_topk_ref(jnp.asarray(s), jnp.asarray(p), k, metric)
    got_v, got_p = merge_topk_pallas(
        jnp.asarray(s), jnp.asarray(p, np.int32), k, metric=metric, tq=8, interpret=True
    )
    got_v, got_p = np.asarray(got_v), np.asarray(got_p, np.int64)
    bad = np.abs(got_v) >= 1e38  # kernel sentinel -> public fill convention
    fill = np.inf if metric == "l2" else -np.inf
    np.testing.assert_array_equal(np.asarray(want_s), np.where(bad, fill, got_v))
    np.testing.assert_array_equal(np.asarray(want_p), np.where(bad, -1, got_p))


def test_merge_topk_empty_and_padding():
    s = np.zeros((3, 0), np.float32)
    p = np.zeros((3, 0), np.int64)
    out_s, out_p = ops.merge_topk(s, p, 5, "l2")
    assert out_s.shape == (3, 5) and np.isinf(out_s).all()
    assert (out_p == -1).all()
    # fewer live candidates than k -> -1 padded tail
    s = np.array([[1.0, 1.0, 2.0]], np.float32)
    p = np.array([[7, 7, 9]], np.int64)
    out_s, out_p = ops.merge_topk(s, p, 5, "l2")
    assert out_p.tolist() == [[7, 9, -1, -1, -1]]
    assert out_s[0, :2].tolist() == [1.0, 2.0]


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_topk_scan_segmented_matches_per_segment(metric):
    rng = np.random.default_rng(3)
    q = rng.standard_normal((9, 24)).astype(np.float32)
    bases, valids = [], []
    for n in (0, 7, 130, 64):
        bases.append(rng.standard_normal((n, 24)).astype(np.float32))
        valids.append(rng.random(n) < 0.7 if n else None)
    k = 11
    fused_v, fused_i = ops.topk_scan_segmented(q, bases, k, metric=metric, valids=valids)
    assert fused_v.shape == (9, len(bases) * k)
    for s_idx, (b, v) in enumerate(zip(bases, valids)):
        want_v, want_i = ops.topk_scan(q, b, k, metric=metric, valid=v)
        blk = slice(s_idx * k, (s_idx + 1) * k)
        got_v, got_i = fused_v[:, blk], fused_i[:, blk]
        # same selected rows; scores equal up to gemm accumulation order
        np.testing.assert_array_equal(want_i, got_i)
        np.testing.assert_allclose(
            np.where(np.isfinite(want_v), want_v, 0.0),
            np.where(np.isfinite(got_v), got_v, 0.0),
            rtol=1e-5,
            atol=1e-4,
        )


def _node_with_segments(rng, dim=12, slice_rows=16):
    """A query node holding sealed-brute + growing segments directly."""
    broker = LogBroker()
    node = QueryNode("qn-test", broker, MemoryObjectStore(), slice_rows=slice_rows)
    coll = "c"
    # two sealed brute segments with interleaved timestamps and deletes
    for sid, n in ((1, 40), (2, 25)):
        seg = Segment(sid, coll, 0, dim, slice_rows=slice_rows)
        seg.append(
            np.arange(sid * 1000, sid * 1000 + n),
            rng.standard_normal((n, dim)).astype(np.float32),
            np.arange(100, 100 + n, dtype=np.int64),
        )
        seg.delete(np.array([sid * 1000 + 3, sid * 1000 + 4]), ts=120)
        node.sealed[(coll, sid)] = SealedHandle(seg)
    # one growing segment: enough rows for full slices + a tail
    seg = Segment(3, coll, 0, dim, slice_rows=slice_rows)
    n = 40
    seg.append(
        np.arange(3000, 3000 + n),
        rng.standard_normal((n, dim)).astype(np.float32),
        np.arange(100, 100 + n, dtype=np.int64),
    )
    from repro.core.query_node import GrowingState

    node.growing[(coll, 3)] = GrowingState(seg)
    node._build_slice_indexes()
    # a duplicated pk across segments (handoff-style) via delta deletes path
    node.delta_deletes[coll] = {1005: 130}
    return node, coll


def _seed_node_search(node, collection, queries, k, metric, ts):
    """The pre-fusion pipeline: per-segment scans + Python merge."""
    pool_s, pool_p = [], []
    mstr = "l2" if metric is Metric.L2 else "ip"
    for (coll, sid), handle in node.sealed.items():
        if coll != collection or handle.segment.num_rows == 0:
            continue
        seg = handle.segment
        mask = node._visible(collection, seg, ts)
        if not mask.any():
            continue
        if handle.index is not None:
            s, i = handle.index.search(queries, k, valid=mask)
        else:
            s, i = ops.topk_scan(queries, seg.vectors(), k, metric=mstr, valid=mask)
        pks = seg.pks()
        pool_s.append(s)
        pool_p.append(np.where(i >= 0, pks[np.clip(i, 0, len(pks) - 1)], -1))
    for (coll, sid), gs in node.growing.items():
        if coll != collection or gs.segment.num_rows == 0:
            continue
        seg = gs.segment
        mask = node._visible(collection, seg, ts)
        pks = seg.pks()
        covered = np.zeros(seg.num_rows, dtype=bool)
        for s_idx, temp in gs.slice_index_built.items():
            lo, hi = seg.slice_bounds(s_idx)
            covered[lo:hi] = True
            if not mask[lo:hi].any():
                continue
            s, i = temp.search(queries, k, valid=mask[lo:hi])
            pool_s.append(s)
            pool_p.append(np.where(i >= 0, pks[lo:hi][np.clip(i, 0, hi - lo - 1)], -1))
        tail_mask = mask & ~covered
        if tail_mask.any():
            s, i = ops.topk_scan(queries, seg.vectors(), k, metric=mstr, valid=tail_mask)
            pool_s.append(s)
            pool_p.append(np.where(i >= 0, pks[np.clip(i, 0, len(pks) - 1)], -1))
    s = np.concatenate(pool_s, axis=1)
    p = np.concatenate(pool_p, axis=1)
    return seed_merge(s, p, k, mstr)


@pytest.mark.parametrize("ts", [110, 125, 10_000])
def test_query_node_engine_matches_seed_pipeline(ts):
    rng = np.random.default_rng(11)
    node, coll = _node_with_segments(rng)
    queries = rng.standard_normal((6, 12)).astype(np.float32)
    k = 8
    g = GuaranteeTs(query_ts=ts, staleness_ms=INFINITE_STALENESS)
    got_s, got_p = node.search(coll, queries, k, Metric.L2, g)
    want_s, want_p = _seed_node_search(node, coll, queries, k, Metric.L2, ts)
    # same selected pks in the same order; scores equal up to gemm order
    np.testing.assert_array_equal(want_p, got_p)
    np.testing.assert_allclose(
        np.where(np.isfinite(want_s), want_s, 0.0),
        np.where(np.isfinite(got_s), got_s, 0.0),
        rtol=1e-5,
        atol=1e-4,
    )


def test_query_node_plan_classes():
    rng = np.random.default_rng(12)
    node, coll = _node_with_segments(rng)
    plan = node.plan_search(coll, 10_000)
    assert len(plan.brute_sealed) == 2
    assert len(plan.growing_slice) == 2  # 40 rows / 16 slice_rows -> 2 full
    assert len(plan.brute_tail) == 1
    assert not plan.indexed
    assert len(plan.units()) == 5
    # queries pinned before any insert see an empty plan
    assert not node.plan_search(coll, 50).units()
