"""Unit tests for the observability plane (``core/telemetry.py``): the
log-bucket histograms, the metrics registry and its Prometheus export,
the bounded control-plane event log, and the per-request span trees."""

import json

import numpy as np
import pytest

from repro.core import ManuConfig, ManuSystem, SearchRequest
from repro.core.request import InsertRequest
from repro.core.telemetry import (
    EventLog,
    Histogram,
    MetricsRegistry,
    TraceContext,
)
from repro.core.timestamp import ManualClock


# ---------------------------------------------------------------- histogram


def test_histogram_percentiles_log_buckets():
    h = Histogram("lat_us")
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=7.0, sigma=1.0, size=20_000)  # ~1.1ms median
    h.record_many(vals)
    assert h.counts.sum() == 20_000
    for q in (50, 95, 99):
        est, exact = h.percentile(q), float(np.percentile(vals, q))
        # log10 buckets at 8/64 decade width: estimate within ~±35%
        assert exact / 1.5 < est < exact * 1.5, (q, est, exact)
    assert h.percentile(50) <= h.percentile(95) <= h.percentile(99)


def test_histogram_edge_values():
    h = Histogram("lat_us")
    assert h.percentile(99) == 0.0  # empty
    h.record(0.0)  # below the first edge: clamps into bucket 0
    h.record(1e12)  # beyond the last edge: clamps into the top bucket
    assert h.counts.sum() == 2
    assert h.mean > 0


# ----------------------------------------------------------------- registry


def test_registry_counters_gauges_labels():
    reg = MetricsRegistry()
    reg.inc("reqs_total")
    reg.inc("reqs_total", 2, labels={"op": "insert"})
    reg.inc("reqs_total", labels={"op": "insert"})
    reg.set_gauge("inflight", 7, labels={"node": "qn-0"})
    assert reg.counter_value("reqs_total") == 1
    assert reg.counter_value("reqs_total", labels={"op": "insert"}) == 3
    assert reg.gauge_value("inflight", labels={"node": "qn-0"}) == 7
    # label order never forks a series
    assert MetricsRegistry._key("m", {"b": 1, "a": 2}) == \
        MetricsRegistry._key("m", {"a": 2, "b": 1})


def test_registry_export_prometheus_text():
    reg = MetricsRegistry()
    reg.inc("searches_total", 5)
    reg.observe("lat_us", 100.0)
    reg.observe("lat_us", 200.0)
    text = reg.export()
    assert "# TYPE searches_total counter" in text
    assert "searches_total 5" in text
    assert "# TYPE lat_us summary" in text
    assert 'lat_us{quantile="0.50"}' in text
    assert "lat_us_count 2" in text


# ---------------------------------------------------------------- event log


def test_event_log_bounded_ring_and_query():
    clock = ManualClock(1000)
    log = EventLog(clock, capacity=4)
    for i in range(6):
        clock.advance(10)
        log.emit("tick", "test", i=i)
    assert len(log) == 4
    assert log.dropped == 2
    assert [e.detail["i"] for e in log.query()] == [2, 3, 4, 5]
    assert [e.detail["i"] for e in log.query(since_ts=1045)] == [4, 5]
    assert [e.kind for e in log.query(kind="nope")] == []
    # numpy payloads become plain JSON types
    e = log.emit("np", "test", sid=np.int64(7), ids=[np.int32(1)])
    d = json.loads(json.dumps(e.to_dict()))
    assert d["detail"] == {"sid": 7, "ids": [1]}


# ------------------------------------------------------------------- traces


def test_trace_context_span_tree():
    ctx = TraceContext("search")
    a = ctx.span("dispatch", node_id="qn-0", segment_ids=(1, 2))
    b = ctx.span("scan", parent=a, node_id="qn-0", segment_ids=(1,))
    b.rows_scanned = 100
    trace = ctx.finish(duration_us=1234.0)
    assert trace.kind == "search"
    assert [s.name for s in trace.walk()] == ["search", "dispatch", "scan"]
    assert trace.spans_named("scan") == [b]
    d = trace.to_dict()
    assert d["root"]["children"][0]["children"][0]["rows_scanned"] == 100
    out = trace.format()
    assert "dispatch" in out and "segments=[1, 2]" in out


# ------------------------------------------------------------- system level


def test_system_metrics_snapshot_and_trace_off_by_default(rng):
    system = ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=300))
    coll = system.create_collection("c", dim=8)
    coll.insert({"vector": rng.standard_normal((900, 8)).astype(np.float32)})
    coll.flush()
    q = rng.standard_normal((2, 8)).astype(np.float32)
    res = coll.search(q, limit=5, staleness_ms=0.0)
    assert res.trace is None  # tracing is opt-in
    mres = coll.insert(InsertRequest({"vector": q}))
    assert mres.trace is None

    snap = system.metrics()
    assert snap.counter("proxy_searches_total") == 1
    assert snap.counter("logger_rows_written_total") == 902
    h = snap.histogram("proxy_search_latency_us")
    assert h is not None and h.count == 1 and h.p99 > 0
    # typed snapshot survives JSON round-trip
    again = json.loads(json.dumps(snap.to_dict()))
    assert again["counters"]["proxy_searches_total"] == 1
    # scan accounting covers the rows actually scanned (masks are
    # per-segment, query-count independent): every sealed row, once
    scanned = sum(
        v for k, v in snap.counters.items()
        if k.startswith("query_node_rows_scanned_total")
    )
    assert scanned == 900


def test_hedge_accounting_splits_primary_and_hedged(rng):
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, replication_factor=2, seal_rows=200)
    )
    coll = system.create_collection("c", dim=8)
    coll.insert({"vector": rng.standard_normal((600, 8)).astype(np.float32)})
    coll.flush()
    q = rng.standard_normal((2, 8)).astype(np.float32)
    straggler = next(
        system.query_nodes[n]
        for n, st in system.query_coord.nodes.items()
        if st.segments
    )
    straggler.inject_delay_s = 2.0
    coll.search(q, limit=10, staleness_ms=0.0, hedge_timeout_s=0.05)
    straggler.inject_delay_s = 0.0
    snap = system.metrics()
    assert snap.counter("proxy_hedges_total") >= 1
    hedged = sum(
        qn.searches_hedged for qn in system.query_nodes.values()
    )
    assert hedged >= 1
    cs = system.cluster_state()
    assert sum(ns.searches_hedged for ns in cs.nodes) == hedged
    # hedged work is excluded from the load the replica picker sees
    for qn in system.query_nodes.values():
        assert qn.inflight_primary <= qn.inflight


def test_control_plane_events_on_failover(rng):
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, replication_factor=2, seal_rows=200)
    )
    coll = system.create_collection("c", dim=8)
    coll.insert({"vector": rng.standard_normal((600, 8)).astype(np.float32)})
    coll.flush()
    mark = system.clock.now_ms()
    victim_id = next(
        n for n, st in system.query_coord.nodes.items() if st.segments
    )
    system.query_nodes[victim_id].alive = False
    system.clock.advance(system.config.heartbeat_ttl_ms + 1)
    system.recover_failures()
    kinds = {e.kind for e in system.events(since_ts=mark)}
    assert "node_dead" in kinds
    assert "node_status_change" in kinds
    dead_events = system.events(kind="node_dead")
    assert dead_events and dead_events[-1].detail["node"] == victim_id
