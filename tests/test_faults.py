"""Fault-injection plane + typed retry/backoff (``core/faults.py``,
``core/retry.py``): seeded determinism, step/op addressing, the
retryable-vs-fatal taxonomy, backoff shape, duplicate delivery, CAS
conflict storms, and the atomic ``FileObjectStore.put`` (torn-write
regression)."""

import os

import numpy as np
import pytest

from repro.core import ManuConfig, ManuSystem
from repro.core.faults import (
    Crash,
    FaultInjector,
    FaultyLogBroker,
    FaultyMetaStore,
    FaultyObjectStore,
)
from repro.core.log import LogBroker, LogEntry, EntryType, Subscription
from repro.core.meta_store import MetaStore
from repro.core.object_store import FileObjectStore, MemoryObjectStore
from repro.core.retry import (
    RetryExhaustedError,
    RetryingMetaStore,
    RetryingObjectStore,
    RetryPolicy,
    TransientStoreError,
)
from repro.core.telemetry import EventLog, MetricsRegistry
from repro.core.timestamp import ManualClock


# ------------------------------------------------------------- injector


def _drive(injector, n=200):
    """Fixed call pattern; returns the op indices where faults fired."""
    fired = []
    for i in range(n):
        site = ("object_store.put", "meta.get", "log.read")[i % 3]
        if injector.check(site, f"key-{i}") is not None:
            fired.append(injector.ops)
    return fired


def _seeded(seed):
    inj = FaultInjector(seed=seed)
    inj.transient("", 0.2)
    return inj


def test_injector_same_seed_same_faults():
    a = _drive(_seeded(42))
    b = _drive(_seeded(42))
    c = _drive(_seeded(43))
    assert a == b
    assert a != c
    assert a  # at 20% over 200 ops something fired


def test_injector_step_and_op_addressing():
    inj = FaultInjector()
    inj.crash_at("object_store.put", 3)  # 3rd matching call
    assert inj.check("object_store.put", "a") is None
    assert inj.check("object_store.get", "b") is None  # other site: no count
    assert inj.check("object_store.put", "b") is None
    rule = inj.check("object_store.put", "c")
    assert rule is not None and rule.kind == "crash"
    # max_fires=1: never again
    assert inj.check("object_store.put", "d") is None

    inj2 = FaultInjector()
    inj2.crash_at_op(5)  # 5th faultable op anywhere
    for i in range(4):
        assert inj2.check(f"site-{i}", "k") is None
    assert inj2.check("anything", "k").kind == "crash"


def test_injector_burst_cap_lets_retries_converge():
    inj = FaultInjector()
    inj.transient("object_store.put", prob=1.0, burst=2)
    assert inj.check("object_store.put", "k") is not None
    assert inj.check("object_store.put", "k") is not None
    assert inj.check("object_store.put", "k") is None  # 3rd in a row suppressed
    assert inj.check("object_store.put", "k") is not None  # streak reset


def test_injector_disarm_and_telemetry():
    metrics, events = MetricsRegistry(), EventLog(ManualClock())
    inj = FaultInjector(metrics=metrics, event_log=events)
    inj.transient("meta.put", prob=1.0, burst=100)
    assert inj.check("meta.put", "x") is not None
    inj.disarm()
    assert inj.check("meta.put", "x") is None
    inj.arm()
    assert inj.check("meta.put", "x") is not None
    assert metrics.counter_value(
        "faults_injected_total", labels={"site": "meta.put", "kind": "transient"}
    ) == 2
    kinds = [e.kind for e in events.query(kind="fault_injected")]
    assert len(kinds) == 2


# ------------------------------------------------------- retry + wrappers


def test_retrying_store_absorbs_transients():
    metrics = MetricsRegistry()
    inj = FaultInjector(seed=1, metrics=metrics)
    inj.transient("object_store.put", prob=1.0, burst=2)  # fail, fail, succeed
    store = RetryingObjectStore(
        FaultyObjectStore(MemoryObjectStore(), inj),
        RetryPolicy(max_attempts=6), metrics=metrics,
    )
    meta = store.put("k", b"v")
    assert meta.size == 1
    assert store.get("k") == b"v"
    assert metrics.counter_value(
        "retry_recovered_total", labels={"site": "object_store.put"}
    ) >= 1
    assert metrics.counter_value(
        "retry_attempts_total", labels={"site": "object_store.put"}
    ) >= 2


def test_retry_budget_exhaustion_is_typed_and_logged():
    metrics, events = MetricsRegistry(), EventLog(ManualClock())
    inj = FaultInjector(seed=1)
    inj.transient("object_store.get", prob=1.0, burst=100)  # never recovers
    store = RetryingObjectStore(
        FaultyObjectStore(MemoryObjectStore(), inj),
        RetryPolicy(max_attempts=3),
        metrics=metrics, event_log=events,
    )
    with pytest.raises(RetryExhaustedError) as ei:
        store.get("missing")
    assert ei.value.site == "object_store.get"
    assert ei.value.attempts == 3
    assert isinstance(ei.value.last, TransientStoreError)
    assert metrics.counter_value(
        "retry_exhausted_total", labels={"site": "object_store.get"}
    ) == 1
    assert events.query(kind="retry_exhausted")


def test_fatal_errors_propagate_unretried():
    metrics = MetricsRegistry()
    store = RetryingObjectStore(MemoryObjectStore(), metrics=metrics)
    with pytest.raises(KeyError):
        store.get("nope")  # semantic error, not infrastructure
    assert metrics.counter_value(
        "retry_attempts_total", labels={"site": "object_store.get"}
    ) == 0


def test_crash_is_never_absorbed_by_retry():
    inj = FaultInjector()
    inj.crash_at("object_store.put", 1)
    store = RetryingObjectStore(FaultyObjectStore(MemoryObjectStore(), inj))
    with pytest.raises(Crash):
        store.put("k", b"v")


def test_retry_policy_backoff_shape():
    import random

    policy = RetryPolicy(base_delay_ms=2.0, multiplier=2.0,
                         max_delay_ms=10.0, jitter=0.5)
    rng = random.Random(0)
    for attempt, nominal in ((1, 2.0), (2, 4.0), (3, 8.0), (4, 10.0), (5, 10.0)):
        d = policy.delay_ms(attempt, rng)
        assert nominal * 0.5 <= d <= nominal * 1.5, (attempt, d)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)


def test_cas_conflict_storm_converges():
    clock = ManualClock()
    inj = FaultInjector(seed=3)
    inj.cas_conflicts(prob=1.0, burst=2)  # every CAS loses twice, then wins
    meta = RetryingMetaStore(FaultyMetaStore(MetaStore(clock), inj))
    wins, rounds = 0, 0
    while wins < 3 and rounds < 50:  # a typical coordinator CAS loop
        rounds += 1
        rev = meta.get_rev("key")
        if meta.cas("key", rev, {"v": wins}):
            wins += 1
    assert wins == 3
    assert rounds > 3  # conflicts actually made the loop spin
    assert meta.get("key") == {"v": 2}


def test_duplicate_delivery_rewinds_reads():
    inj = FaultInjector()
    inj.duplicates(prob=1.0, rewind=2, max_fires=1)
    broker = FaultyLogBroker(LogBroker(), inj)
    broker.create_channel("ch")
    for i in range(5):
        broker.publish("ch", LogEntry(ts=i + 1, type=EntryType.TIME_TICK, payload={}))
    sub = Subscription(broker, "ch")
    first = sub.poll()  # duplicate rule fires: from_position=0, no rewind room
    assert [e.ts for e in first] == [1, 2, 3, 4, 5]
    broker.publish("ch", LogEntry(ts=6, type=EntryType.TIME_TICK, payload={}))
    inj.duplicates(prob=1.0, rewind=2, max_fires=1)
    again = sub.poll()  # re-delivers entries 4,5 plus the new 6
    assert [e.ts for e in again] == [4, 5, 6]
    # cursor still lands past the end; no livelock
    assert sub.lag() == 0


# ------------------------------------- satellite 1: atomic FileObjectStore


def test_file_store_torn_write_regression(tmp_path):
    """A crash mid-``put`` must never tear or half-publish an object: the
    write goes to a private ``.tmp`` staged file and ``os.replace`` is the
    atomic commit point."""
    store = FileObjectStore(str(tmp_path))
    store.put("seg/1/meta", b"old")

    real_replace = os.replace
    calls = {"n": 0}

    def dying_replace(src, dst):
        calls["n"] += 1
        raise Crash("object_store.put", 1, "seg/1/meta")

    os.replace = dying_replace
    try:
        with pytest.raises(Crash):
            store.put("seg/1/meta", b"NEW-BUT-NEVER-COMMITTED")
    finally:
        os.replace = real_replace
    assert calls["n"] == 1
    # the published object is intact, the stranded tmp is invisible
    assert store.get("seg/1/meta") == b"old"
    assert [m.key for m in store.list("seg/")] == ["seg/1/meta"]
    # and a later put of the same key succeeds cleanly
    store.put("seg/1/meta", b"new")
    assert store.get("seg/1/meta") == b"new"
    leftovers = [f for f in os.listdir(tmp_path / "seg" / "1") if ".tmp" in f]
    assert leftovers == []


def test_file_store_interrupted_write_leaves_no_partial(tmp_path, monkeypatch):
    """Die inside the data write itself (before the commit point): no
    object appears at all and the staging file is cleaned up."""
    import builtins

    store = FileObjectStore(str(tmp_path))
    real_open = builtins.open

    class HalfThenDie:
        def __init__(self, f):
            self.f = f

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            self.f.close()
            return False

        def write(self, data):
            self.f.write(data[: len(data) // 2])  # torn write...
            raise Crash("object_store.put", 1, "a/b")  # ...then the kill

    def exploding_open(path, mode="r", *a, **kw):
        f = real_open(path, mode, *a, **kw)
        if str(path).endswith(".tmp") and "w" in mode:
            return HalfThenDie(f)
        return f

    monkeypatch.setattr(builtins, "open", exploding_open)
    with pytest.raises(Crash):
        store.put("a/b", b"0123456789")
    monkeypatch.undo()
    assert not store.exists("a/b")
    assert list(store.list("")) == []


# ------------------------------------------------- end-to-end with faults


def test_system_absorbs_transient_store_faults(rng):
    """10% transient faults at every object-store op: the retry plane keeps
    the whole ingest -> seal -> index -> search pipeline correct."""
    inj = FaultInjector(seed=11)
    inj.transient("object_store.put", prob=0.1)
    inj.transient("object_store.get", prob=0.1)
    faulty = ManuSystem(
        ManuConfig(num_query_nodes=2, seal_rows=100, num_shards=2),
        injector=inj,
    )
    oracle = ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=100, num_shards=2))
    vecs = rng.standard_normal((300, 8)).astype(np.float32)
    q = vecs[:5]
    for system in (faulty, oracle):
        coll = system.create_collection("c", dim=8)
        coll.insert({"vector": vecs})
        coll.flush()
        coll.create_index("vector", kind="flat")
    got = faulty.collections["c"].search(q, limit=10, staleness_ms=0.0)
    want = oracle.collections["c"].search(q, limit=10, staleness_ms=0.0)
    np.testing.assert_array_equal(got.pks, want.pks)
    counters = faulty.metrics().to_dict()["counters"]
    assert any(k.startswith("faults_injected_total") for k in counters)
    assert any(k.startswith("retry_recovered_total") for k in counters)


def test_system_dedups_duplicate_log_delivery(rng):
    """An at-least-once broker (duplicate re-delivery on every read chance)
    must not double-apply rows or tombstones anywhere."""
    inj = FaultInjector(seed=5)
    inj.duplicates(prob=0.2, rewind=3)
    faulty = ManuSystem(
        ManuConfig(num_query_nodes=2, seal_rows=100, num_shards=2),
        injector=inj,
    )
    oracle = ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=100, num_shards=2))
    vecs = rng.standard_normal((250, 8)).astype(np.float32)
    q = vecs[:4]
    for system in (faulty, oracle):
        coll = system.create_collection("c", dim=8)
        coll.insert({"vector": vecs})
        coll.delete(np.arange(0, 50))
        coll.flush()
    # duplicate delivery must not double-apply rows (tombstones don't
    # shrink segment rows until compaction, so 250 == exactly-once)
    assert faulty.collections["c"].num_entities() == 250
    assert oracle.collections["c"].num_entities() == 250
    got = faulty.collections["c"].search(q, limit=10, staleness_ms=0.0)
    want = oracle.collections["c"].search(q, limit=10, staleness_ms=0.0)
    np.testing.assert_array_equal(got.pks, want.pks)
    assert not ({int(p) for p in got.pks.ravel() if p >= 0} & set(range(50)))
