"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracles,
swept over shapes and dtypes."""

import os

import numpy as np
import pytest

os.environ.setdefault("REPRO_FORCE_PALLAS", "0")

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.l2_topk import l2_topk_pallas
from repro.kernels.pq_adc import pq_adc_topk_pallas
from repro.kernels.sq_codec import (
    sq_decode_pallas,
    sq_encode_pallas,
    sq_l2_topk_pallas,
)

SHAPES = [
    # (nq, n, d, k)
    (8, 128, 32, 5),
    (16, 512, 64, 10),
    (32, 1024, 128, 50),
    (8, 256, 16, 17),
]
DTYPES = [np.float32, np.float16]


def _pad(a, m, fill=0.0):
    pad = (-a.shape[0]) % m
    if pad == 0:
        return a
    w = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, w, constant_values=fill)


@pytest.mark.parametrize("nq,n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_topk_scan_matches_ref(rng, nq, n, d, k, dtype, metric):
    q = rng.standard_normal((nq, d)).astype(dtype)
    x = rng.standard_normal((n, d)).astype(dtype)
    valid = (rng.random(n) > 0.15).astype(np.int32)

    tq = min(128, max(8, nq))
    tn = min(512, max(128, n))
    qp = _pad(q.astype(np.float32), tq)
    xp = _pad(x.astype(np.float32), tn)
    vp = _pad(valid, tn)
    vals, idx = l2_topk_pallas(
        jnp.asarray(qp), jnp.asarray(xp), jnp.asarray(vp), k,
        metric=metric, tq=tq, tn=tn, interpret=True,
    )
    vals, idx = np.asarray(vals)[:nq], np.asarray(idx)[:nq]

    fn = ref.l2_topk_ref if metric == "l2" else ref.ip_topk_ref
    rv, ri = fn(jnp.asarray(q, jnp.float32), jnp.asarray(x, jnp.float32), k,
                valid=jnp.asarray(valid, bool))
    rv, ri = np.asarray(rv), np.asarray(ri)
    np.testing.assert_allclose(vals, rv, rtol=3e-4, atol=3e-4)
    # indices may differ at exact-tie distances; values must agree
    agree = (idx == ri).mean()
    assert agree > 0.9, f"index agreement {agree}"


def test_topk_all_invalid(rng):
    q = rng.standard_normal((8, 32)).astype(np.float32)
    x = rng.standard_normal((128, 32)).astype(np.float32)
    valid = np.zeros(128, np.int32)
    vals, idx = l2_topk_pallas(
        jnp.asarray(q), jnp.asarray(x), jnp.asarray(valid), 5,
        tq=8, tn=128, interpret=True,
    )
    assert (np.asarray(vals) >= 1e38).all()


@pytest.mark.parametrize("nq,n,m,ksub,k", [(4, 256, 8, 256, 10), (8, 512, 16, 256, 5)])
def test_pq_adc_matches_ref(rng, nq, n, m, ksub, k):
    luts = rng.standard_normal((nq, m, ksub)).astype(np.float32)
    codes = rng.integers(0, ksub, (n, m)).astype(np.int32)
    valid = (rng.random(n) > 0.1).astype(np.int32)
    vals, idx = pq_adc_topk_pallas(
        jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(valid), k,
        tn=min(512, n), interpret=True,
    )
    rv, ri = ref.pq_adc_topk_ref(jnp.asarray(luts), jnp.asarray(codes), k,
                                 valid=jnp.asarray(valid, bool))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,d", [(256, 32), (512, 128)])
def test_sq_roundtrip_and_scan(rng, n, d):
    x = rng.standard_normal((n, d)).astype(np.float32) * 3
    vmin, vmax = x.min(0), x.max(0)
    codes = sq_encode_pallas(jnp.asarray(x), jnp.asarray(vmin), jnp.asarray(vmax),
                             tn=min(512, n), interpret=True)
    rcodes = np.asarray(ref.sq_encode_ref(jnp.asarray(x), jnp.asarray(vmin), jnp.asarray(vmax)))
    # allow 1-ulp rounding ties
    assert np.abs(np.asarray(codes).astype(int) - rcodes.astype(int)).max() <= 1

    dec = sq_decode_pallas(codes, jnp.asarray(vmin), jnp.asarray(vmax),
                           tn=min(512, n), interpret=True)
    scale = np.maximum(vmax - vmin, 1e-12) / 255.0
    assert np.abs(np.asarray(dec) - x).max() <= scale.max() * 1.01  # quant error bound

    q = rng.standard_normal((8, d)).astype(np.float32)
    valid = np.ones(n, np.int32)
    vals, idx = sq_l2_topk_pallas(
        jnp.asarray(q), codes, jnp.asarray(vmin), jnp.asarray(vmax),
        jnp.asarray(valid), 10, tq=8, tn=min(512, n), interpret=True,
    )
    rv, ri = ref.sq_l2_topk_ref(jnp.asarray(q), codes, jnp.asarray(vmin),
                                jnp.asarray(vmax), 10)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,c,d", [(256, 16, 32), (512, 512, 64), (512, 600, 16)])
def test_kmeans_assign_matches_ref(rng, n, c, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    cents = rng.standard_normal((c, d)).astype(np.float32)
    tn = min(512, n)
    tc = 512 if c >= 512 else max(128, 1 << (c - 1).bit_length())
    pad_c = (-c) % tc
    cp = np.concatenate([cents, np.full((pad_c, d), 1e18, np.float32)]) if pad_c else cents
    a, dist = kmeans_assign_pallas(jnp.asarray(x), jnp.asarray(cp), tn=tn, tc=tc, interpret=True)
    ra, rd = ref.kmeans_assign_ref(jnp.asarray(x), jnp.asarray(cents))
    assert (np.asarray(a) == np.asarray(ra)).all()
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rd), rtol=3e-4, atol=3e-4)


def test_ops_dispatch_consistency(rng):
    """The public ops wrappers (numpy fast path) match the oracles."""
    from repro.kernels import ops

    q = rng.standard_normal((6, 24)).astype(np.float32)
    x = rng.standard_normal((300, 24)).astype(np.float32)
    valid = rng.random(300) > 0.2
    for metric in ("l2", "ip"):
        v, i = ops.topk_scan(q, x, 7, metric=metric, valid=valid)
        fn = ref.l2_topk_ref if metric == "l2" else ref.ip_topk_ref
        rv, ri = fn(jnp.asarray(q), jnp.asarray(x), 7, valid=jnp.asarray(valid))
        np.testing.assert_allclose(v, np.asarray(rv), rtol=1e-4, atol=1e-4)

    # k > n edge case
    v, i = ops.topk_scan(q, x[:3], 10)
    assert (i[:, 3:] == -1).all()
    # empty base
    v, i = ops.topk_scan(q, x[:0], 4)
    assert (i == -1).all()
