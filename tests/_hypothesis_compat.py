"""Optional-``hypothesis`` shim for offline environments.

When hypothesis is installed (CI installs it via the ``test`` extra) the
real library is used unchanged.  When it is missing, a tiny seeded
random-sampling fallback runs each property test over a fixed number of
generated examples, so the property suites still execute instead of
erroring at collection.  The fallback covers only the strategy surface
these tests use: ``integers``, ``floats``, ``just``, ``one_of``,
``lists``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by which env runs the suite
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            # Bias toward the bounds: property tests lean on edge values.
            def sample(rng):
                r = rng.random()
                if r < 0.05:
                    return float(min_value)
                if r < 0.1:
                    return float(max_value)
                return rng.uniform(min_value, max_value)

            return _Strategy(sample)

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def one_of(*strategies):
            return _Strategy(lambda rng: rng.choice(strategies).sample(rng))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    elements.sample(rng)
                    for _ in range(rng.randint(min_size, max_size))
                ]
            )

    st = _Strategies()

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            # NB: no functools.wraps — copying __wrapped__ would expose the
            # original signature and make pytest treat params as fixtures.
            def wrapper():
                rng = random.Random(0xC0FFEE)
                for _ in range(getattr(fn, "_max_examples", 25)):
                    args = [s.sample(rng) for s in arg_strategies]
                    kwargs = {k: s.sample(rng) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return decorate

    def settings(max_examples=25, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
