"""The declarative request API: hybrid multi-vector search, range search,
filter composition, output-field hydration, consistency levels, and the
back-compat kwarg facade — all checked against independent numpy oracles.

The oracle deliberately re-implements the documented semantics with
per-row Python loops (no shared code with ``ops.hybrid_fuse`` /
``ops.range_cut``):

* per-field scores are brute force over ALL rows,
* each field's candidate list is its global top-k,
* weighted fusion sums ``w_f * sim`` over the lists a pk appears in
  (L2 ``1/(1+d)``, cosine ``(1+s)/2``, IP ``1/(1+exp(-s))``),
* RRF sums ``w_f / (rrf_k + rank)`` with 1-based ranks,
* range search keeps the in-bounds subset of the global top-k
  (L2: ``range_filter <= d < radius``; IP: ``radius < s <= range_filter``).
"""

import numpy as np
import pytest

from repro.core import (
    AnnsQuery,
    ConsistencyLevel,
    FieldSchema,
    FieldType,
    ManuConfig,
    ManuSystem,
    Metric,
    Ranker,
    SearchRequest,
)

DIM1, DIM2 = 12, 6


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------


def field_scores(metric: Metric, q: np.ndarray, base: np.ndarray) -> np.ndarray:
    """Brute-force scores with the engine's gemm expansion (L2) or inner
    product (IP / cosine over unit vectors)."""
    q = np.asarray(q, np.float32)
    base = np.asarray(base, np.float32)
    if metric is Metric.L2:
        return (
            np.sum(q * q, 1, keepdims=True)
            - 2.0 * q @ base.T
            + np.sum(base * base, 1)[None, :]
        )
    if metric is Metric.COSINE:
        qn = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-12)
        bn = base / np.maximum(np.linalg.norm(base, axis=1, keepdims=True), 1e-12)
        return qn @ bn.T
    return q @ base.T


def field_topk(metric: Metric, scores: np.ndarray, k: int):
    """Global per-field top-k candidate list (best-first pks + scores)."""
    order = np.argsort(scores if metric is Metric.L2 else -scores, axis=1)[:, :k]
    return order, np.take_along_axis(scores, order, axis=1)


def sim_of(metric: Metric, s: float) -> float:
    if metric is Metric.L2:
        return 1.0 / (1.0 + max(float(s), 0.0))
    if metric is Metric.COSINE:
        return (1.0 + float(s)) / 2.0
    return 1.0 / (1.0 + np.exp(-np.float32(s)))


def oracle_hybrid(metric, queries_per_field, bases, weights, k, ranker):
    """Per-row dict-accumulate fusion over per-field global top-k lists."""
    nq = len(queries_per_field[0])
    out = []
    for r in range(nq):
        acc: dict[int, float] = {}
        for f, (q, base) in enumerate(zip(queries_per_field, bases)):
            s = field_scores(metric, q[r : r + 1], base)
            pks, vals = field_topk(metric, s, k)
            for rank, (pk, v) in enumerate(zip(pks[0], vals[0])):
                if ranker.kind == "rrf":
                    c = weights[f] / (ranker.rrf_k + rank + 1.0)
                else:
                    c = weights[f] * float(
                        np.float64(sim_of(metric, np.float32(v)))
                    )
                acc[int(pk)] = acc.get(int(pk), 0.0) + c
        top = sorted(acc.items(), key=lambda kv: -kv[1])[:k]
        out.append([pk for pk, _v in top])
    return out


def oracle_range(metric, q, base, k, radius=None, range_filter=None):
    """In-bounds subset of the global top-k, order preserved."""
    s = field_scores(metric, q, base)
    pks, vals = field_topk(metric, s, k)
    out = []
    for r in range(len(q)):
        keep = []
        for pk, v in zip(pks[r], vals[r]):
            if metric is Metric.L2:
                if radius is not None and not (v < radius):
                    continue
                if range_filter is not None and not (v >= range_filter):
                    continue
            else:
                if radius is not None and not (v > radius):
                    continue
                if range_filter is not None and not (v <= range_filter):
                    continue
            keep.append(int(pk))
        out.append(keep)
    return out


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


def make_system():
    return ManuSystem(
        ManuConfig(num_query_nodes=2, seal_rows=200, slice_rows=4096, num_shards=2)
    )


def make_collection(system, metric: Metric, rng, n=450, name="c"):
    coll = system.create_collection(
        name,
        dim=DIM1,
        metric=metric,
        extra_fields=[
            FieldSchema("img_vec", FieldType.VECTOR, dim=DIM2),
            FieldSchema("price", FieldType.FLOAT),
        ],
    )
    v1 = rng.standard_normal((n, DIM1)).astype(np.float32)
    v2 = rng.standard_normal((n, DIM2)).astype(np.float32)
    price = rng.uniform(0, 100, n)
    coll.insert({"vector": v1, "img_vec": v2, "price": price})
    coll.flush()
    return coll, v1, v2, price


METRICS = [Metric.L2, Metric.IP, Metric.COSINE]
RANKERS = [Ranker.weighted(), Ranker.rrf(10.0)]


# ---------------------------------------------------------------------------
# hybrid search
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", METRICS, ids=[m.value for m in METRICS])
@pytest.mark.parametrize("ranker", RANKERS, ids=["weighted", "rrf"])
def test_hybrid_matches_oracle(metric, ranker, rng):
    system = make_system()
    coll, v1, v2, _price = make_collection(system, metric, rng)
    nq, k = 4, 8
    q1 = rng.standard_normal((nq, DIM1)).astype(np.float32)
    q2 = rng.standard_normal((nq, DIM2)).astype(np.float32)
    weights = [0.7, 0.3] if ranker.kind == "weighted" else [1.0, 1.0]
    res = coll.search(
        SearchRequest(
            anns=[
                AnnsQuery("vector", q1, weight=weights[0]),
                AnnsQuery("img_vec", q2, weight=weights[1]),
            ],
            k=k,
            staleness_ms=0.0,
            ranker=ranker,
        )
    )
    want = oracle_hybrid(metric, [q1, q2], [v1, v2], weights, k, ranker)
    for r in range(nq):
        assert res.pks[r].tolist() == want[r], f"row {r} ({metric}, {ranker.kind})"
    # fused scores are descending and finite on live slots
    live = res.scores[res.pks >= 0]
    assert np.isfinite(live).all()
    assert (np.diff(res.scores, axis=1) <= 1e-12).all()


def test_hybrid_weight_shifts_ranking(rng):
    """Extreme weights must collapse the hybrid ranking onto one field."""
    system = make_system()
    coll, v1, v2, _ = make_collection(system, Metric.L2, rng)
    q1 = rng.standard_normal((2, DIM1)).astype(np.float32)
    q2 = rng.standard_normal((2, DIM2)).astype(np.float32)
    k = 5

    def run(w1, w2):
        return coll.search(
            SearchRequest(
                anns=[AnnsQuery("vector", q1, weight=w1),
                      AnnsQuery("img_vec", q2, weight=w2)],
                k=k, staleness_ms=0.0,
            )
        ).pks

    only_1 = run(1.0, 0.0)
    only_2 = run(0.0, 1.0)
    base_1 = coll.search(q1, limit=k, staleness_ms=0.0).pks
    s2 = field_scores(Metric.L2, q2, v2)
    gt2, _ = field_topk(Metric.L2, s2, k)
    np.testing.assert_array_equal(only_1, base_1)
    np.testing.assert_array_equal(only_2, gt2)


def test_hybrid_with_indexes_exhaustive_stays_exact(rng):
    """nprobe == nlist IVF on both fields is exhaustive -> same pks as the
    brute-force oracle."""
    system = make_system()
    coll, v1, v2, _ = make_collection(system, Metric.L2, rng)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 4, "nprobe": 4})
    coll.create_index("img_vec", kind="ivf_flat", params={"nlist": 4, "nprobe": 4})
    assert system.stats()["index_builds"] >= 2
    nq, k = 3, 6
    q1 = rng.standard_normal((nq, DIM1)).astype(np.float32)
    q2 = rng.standard_normal((nq, DIM2)).astype(np.float32)
    res = coll.search(
        SearchRequest(
            anns=[AnnsQuery("vector", q1, weight=0.5),
                  AnnsQuery("img_vec", q2, weight=0.5)],
            k=k, staleness_ms=0.0,
        )
    )
    want = oracle_hybrid(Metric.L2, [q1, q2], [v1, v2], [0.5, 0.5], k,
                         Ranker.weighted())
    for r in range(nq):
        assert res.pks[r].tolist() == want[r]


# ---------------------------------------------------------------------------
# range search
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", [Metric.L2, Metric.IP],
                         ids=["l2", "ip"])
def test_range_search_matches_oracle(metric, rng):
    system = make_system()
    coll, v1, _v2, _ = make_collection(system, metric, rng)
    nq, k = 3, 12
    q = rng.standard_normal((nq, DIM1)).astype(np.float32)
    s = field_scores(metric, q, v1)
    srt = np.sort(s, axis=1)
    if metric is Metric.L2:
        radius = float(srt[0, 6]) + 1e-5  # ~6 rows inside for row 0
        range_filter = float(srt[0, 1])  # excludes the single nearest row
    else:
        radius = float(srt[0, -7]) - 1e-5
        range_filter = float(srt[0, -2])
    res = coll.search(
        q, limit=k, staleness_ms=0.0, radius=radius, range_filter=range_filter
    )
    want = oracle_range(metric, q, v1, k, radius, range_filter)
    for r in range(nq):
        live = res.pks[r][res.pks[r] >= 0]
        assert live.tolist() == want[r]
    # cut slots are fully dead (fill score, pk -1), and live scores in bounds
    dead = res.pks < 0
    fill = np.inf if metric is Metric.L2 else -np.inf
    assert (res.scores[dead] == fill).all()
    live_s = res.scores[res.pks >= 0]
    if metric is Metric.L2:
        assert ((live_s < radius) & (live_s >= range_filter)).all()
    else:
        assert ((live_s > radius) & (live_s <= range_filter)).all()


def test_filter_and_range_compose(rng):
    """radius cut applies to the top-k of the FILTERED candidate set."""
    system = make_system()
    coll, v1, _v2, price = make_collection(system, Metric.L2, rng)
    nq, k = 2, 10
    q = rng.standard_normal((nq, DIM1)).astype(np.float32)
    sel = price < 40
    s = field_scores(Metric.L2, q, v1[sel])
    radius = float(np.sort(s[0])[7]) + 1e-5
    res = coll.search(
        q, limit=k, staleness_ms=0.0, filter_expr="price < 40", radius=radius
    )
    want = oracle_range(Metric.L2, q, v1[sel], k, radius)
    sel_ids = np.nonzero(sel)[0]
    for r in range(nq):
        live = res.pks[r][res.pks[r] >= 0]
        assert live.tolist() == [int(sel_ids[i]) for i in want[r]]
        assert (price[live] < 40).all()


def test_per_field_radius_params_override(rng):
    """AnnsQuery.params radius overrides the request-level bound per field."""
    system = make_system()
    coll, v1, v2, _ = make_collection(system, Metric.L2, rng)
    q1 = rng.standard_normal((1, DIM1)).astype(np.float32)
    q2 = rng.standard_normal((1, DIM2)).astype(np.float32)
    k = 6
    s1 = np.sort(field_scores(Metric.L2, q1, v1)[0])
    tight = float(s1[2]) + 1e-5  # field 1 keeps only 3 candidates
    res = coll.search(
        SearchRequest(
            anns=[
                AnnsQuery("vector", q1, params={"radius": tight}),
                AnnsQuery("img_vec", q2),
            ],
            k=k,
            staleness_ms=0.0,
            ranker=Ranker.rrf(10.0),
        )
    )
    # with field 2 uncut, fusion still returns k live candidates
    assert (res.pks[0] >= 0).sum() == k
    want = set()
    pks1 = oracle_range(Metric.L2, q1, v1, k, tight)[0]
    s2 = field_scores(Metric.L2, q2, v2)
    pks2, _ = field_topk(Metric.L2, s2, k)
    cand = set(pks1) | set(int(p) for p in pks2[0])
    assert set(res.pks[0].tolist()) <= cand


# ---------------------------------------------------------------------------
# output-field hydration
# ---------------------------------------------------------------------------


def test_output_fields_hydration(rng):
    system = make_system()
    coll, v1, v2, price = make_collection(system, Metric.L2, rng)
    q = rng.standard_normal((3, DIM1)).astype(np.float32)
    res = coll.search(
        q, limit=5, staleness_ms=0.0, output_fields=("price", "pk", "img_vec")
    )
    assert res.fields is not None
    pks = res.pks
    np.testing.assert_allclose(res.fields["price"], price[pks], rtol=0, atol=0)
    np.testing.assert_array_equal(res.fields["pk"], pks)
    assert res.fields["img_vec"].shape == (3, 5, DIM2)
    np.testing.assert_array_equal(res.fields["img_vec"], v2[pks])


def test_output_fields_hydration_empty_slots(rng):
    """Range-cut holes hydrate as NaN, not as some row's value."""
    system = make_system()
    coll, v1, _v2, price = make_collection(system, Metric.L2, rng)
    q = rng.standard_normal((1, DIM1)).astype(np.float32)
    s = np.sort(field_scores(Metric.L2, q, v1)[0])
    res = coll.search(
        q, limit=8, staleness_ms=0.0, radius=float(s[3]) + 1e-5,
        output_fields=("price",),
    )
    live = res.pks[0] >= 0
    assert live.sum() == 4
    np.testing.assert_allclose(res.fields["price"][0][live], price[res.pks[0][live]])
    assert np.isnan(res.fields["price"][0][~live]).all()


def test_hydration_covers_growing_rows(rng):
    """Rows still in growing segments (never flushed) hydrate too."""
    system = make_system()
    coll = system.create_collection(
        "g", dim=DIM1,
        extra_fields=[FieldSchema("price", FieldType.FLOAT)],
    )
    v = rng.standard_normal((60, DIM1)).astype(np.float32)
    price = rng.uniform(0, 9, 60)
    coll.insert({"vector": v, "price": price})
    q = rng.standard_normal((1, DIM1)).astype(np.float32)
    res = coll.search(q, limit=4, staleness_ms=0.0, output_fields=("price",))
    assert (res.pks[0] >= 0).all()
    np.testing.assert_allclose(res.fields["price"][0], price[res.pks[0]])


# ---------------------------------------------------------------------------
# back-compat facade & consistency levels
# ---------------------------------------------------------------------------


def test_legacy_kwargs_equal_explicit_request(rng):
    system = make_system()
    coll, v1, _v2, price = make_collection(system, Metric.L2, rng)
    q = rng.standard_normal((3, DIM1)).astype(np.float32)
    legacy = coll.search(q, limit=7, staleness_ms=0.0, filter_expr="price < 60")
    explicit = coll.search(
        SearchRequest.single(
            q, field="vector", k=7, staleness_ms=0.0, filter="price < 60"
        )
    )
    np.testing.assert_array_equal(legacy.pks, explicit.pks)
    np.testing.assert_array_equal(legacy.scores, explicit.scores)


def test_consistency_level_strong_equals_staleness_zero(rng):
    system = make_system()
    coll = system.create_collection("c", dim=DIM1)
    v = rng.standard_normal((300, DIM1)).astype(np.float32)
    coll.insert({"vector": v})
    q = rng.standard_normal((2, DIM1)).astype(np.float32)
    via_level = coll.search(
        SearchRequest.single(q, k=5, consistency=ConsistencyLevel.STRONG)
    )
    via_tau = coll.search(q, limit=5, staleness_ms=0.0)
    np.testing.assert_array_equal(via_level.pks, via_tau.pks)


def test_session_consistency_reads_own_writes(rng):
    system = make_system()
    coll = system.create_collection("c", dim=DIM1)
    coll.insert({"vector": rng.standard_normal((40, DIM1)).astype(np.float32)})
    q = rng.standard_normal((1, DIM1)).astype(np.float32)
    res = coll.search(
        SearchRequest.single(q, k=5, consistency=ConsistencyLevel.SESSION)
    )
    assert (res.pks[0] >= 0).sum() == 5


def test_session_consistency_via_legacy_kwargs(rng):
    """consistency=SESSION through the kwarg facade must wait for the
    handle's last write, same as read_your_writes=True."""
    system = make_system()
    coll = system.create_collection("c", dim=DIM1)
    coll.insert({"vector": rng.standard_normal((40, DIM1)).astype(np.float32)})
    q = rng.standard_normal((1, DIM1)).astype(np.float32)
    res = coll.search(q, limit=5, consistency=ConsistencyLevel.SESSION)
    assert (res.pks[0] >= 0).sum() == 5


def test_reused_session_request_not_mutated(rng):
    """A caller-owned SESSION request must not be mutated: reusing it after
    a later write still reads that later write."""
    system = make_system()
    coll = system.create_collection("c", dim=DIM1)
    base = rng.standard_normal((30, DIM1)).astype(np.float32)
    coll.insert({"vector": base})
    probe = (base[0] + 1e-3).reshape(1, -1).astype(np.float32)
    req = SearchRequest.single(probe, k=1, consistency=ConsistencyLevel.SESSION)
    coll.search(req)
    assert req.session_ts == 0  # untouched
    # a row exactly at the probe, written AFTER the first search
    coll.insert({"pk": np.array([777]), "vector": probe})
    res = coll.search(req)
    assert res.pks[0][0] == 777


def test_inverted_range_bounds_rejected(rng):
    """An always-empty range window (swapped bounds) raises instead of
    silently returning nothing."""
    system = make_system()
    coll, *_ = make_collection(system, Metric.L2, rng, n=60)
    q = rng.standard_normal((1, DIM1)).astype(np.float32)
    with pytest.raises(ValueError, match="range window is empty"):
        coll.search(q, limit=5, staleness_ms=0.0, radius=1.0, range_filter=1e9)
    ip_sys = make_system()
    ip_coll, *_ = make_collection(ip_sys, Metric.IP, rng, n=60)
    with pytest.raises(ValueError, match="range window is empty"):
        ip_coll.search(q, limit=5, staleness_ms=0.0, radius=1e9, range_filter=1.0)


def test_empty_hydration_keeps_vector_shape(rng):
    """When the range cut removes every candidate, vector output fields
    still hydrate with the documented [nq, k, dim] shape."""
    system = make_system()
    coll, *_ = make_collection(system, Metric.L2, rng, n=60)
    q = rng.standard_normal((2, DIM1)).astype(np.float32)
    res = coll.search(
        q, limit=5, staleness_ms=0.0, radius=1e-12,
        output_fields=("img_vec", "price"),
    )
    assert (res.pks < 0).all()
    assert res.fields["img_vec"].shape == (2, 5, DIM2)
    assert np.isnan(res.fields["img_vec"]).all()
    assert res.fields["price"].shape == (2, 5)


def test_request_validation_rejects_bad_fields(rng):
    system = make_system()
    coll, *_ = make_collection(system, Metric.L2, rng, n=60)
    q_ok = rng.standard_normal((1, DIM1)).astype(np.float32)
    with pytest.raises(KeyError):
        coll.search(SearchRequest.single(q_ok, field="nope", k=3))
    with pytest.raises(ValueError):
        coll.search(SearchRequest.single(q_ok, field="price", k=3))
    with pytest.raises(ValueError):  # dim mismatch
        coll.search(SearchRequest.single(q_ok, field="img_vec", k=3))
    with pytest.raises(ValueError):  # duplicate anns field
        coll.search(
            SearchRequest(
                anns=[AnnsQuery("vector", q_ok), AnnsQuery("vector", q_ok)], k=3
            )
        )


@pytest.mark.parametrize("metric", [Metric.COSINE, Metric.IP],
                         ids=["cosine", "ip"])
def test_growing_slices_stay_exact_for_non_l2_metrics(metric, rng):
    """Temp slice indexes are built L2 off the WAL; for IP/cosine requests
    the planner must skip them (brute tail) so growing reads match the
    oracle exactly."""
    system = ManuSystem(
        ManuConfig(num_query_nodes=1, seal_rows=10_000, slice_rows=64,
                   num_shards=1)
    )
    coll = system.create_collection("c", dim=DIM1, metric=metric)
    v = rng.standard_normal((300, DIM1)).astype(np.float32)
    coll.insert({"vector": v})  # stays growing; slices 0..3 get temp indexes
    assert any(
        gs.slice_index_built
        for qn in system.query_nodes.values()
        for gs in qn.growing.values()
    )
    q = rng.standard_normal((3, DIM1)).astype(np.float32)
    res = coll.search(q, limit=6, staleness_ms=0.0)
    s = field_scores(metric, q, v)
    want, _ = field_topk(metric, s, 6)
    np.testing.assert_array_equal(res.pks, want)


# ---------------------------------------------------------------------------
# satellite regressions: num_entities & create_index validation
# ---------------------------------------------------------------------------


def test_num_entities_is_per_collection_and_dedups_replicas(rng):
    system = make_system()
    a = system.create_collection("a", dim=DIM1)
    b = system.create_collection("b", dim=DIM1)
    a.insert({"vector": rng.standard_normal((300, DIM1)).astype(np.float32)})
    b.insert({"vector": rng.standard_normal((120, DIM1)).astype(np.float32)})
    a.flush()
    assert a.num_entities() == 300
    assert b.num_entities() == 120
    # replicate every sealed segment of "a" onto BOTH query nodes: the
    # count must not change (the seed implementation double-counted here
    # and summed both collections).
    for sid in system.data_coord.sealed_segments("a"):
        for qn in system.query_nodes.values():
            qn.load_sealed("a", sid)
    assert a.num_entities() == 300
    assert b.num_entities() == 120


def test_create_index_accepts_named_vector_field_rejects_scalars(rng):
    system = make_system()
    coll, *_ = make_collection(system, Metric.L2, rng, n=220)
    coll.create_index("img_vec", kind="ivf_flat", params={"nlist": 4, "nprobe": 4})
    with pytest.raises(ValueError):
        coll.create_index("price", kind="flat")
    with pytest.raises(KeyError):
        coll.create_index("missing", kind="flat")
    system.run_until_idle()
    # the named field got its own per-field index objects
    keys = [m.key for m in system.store.list("index/c/")]
    assert keys and all("/img_vec/" in key for key in keys)


# ---------------------------------------------------------------------------
# fuzz: hybrid × metric × ranker × filter against the oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_fuzz_hybrid_filter_range(seed):
    rng = np.random.default_rng(seed)
    system = make_system()
    metric = [Metric.L2, Metric.IP, Metric.COSINE][seed % 3]
    coll, v1, v2, price = make_collection(system, metric, rng, n=260)
    nq, k = 3, 7
    q1 = rng.standard_normal((nq, DIM1)).astype(np.float32)
    q2 = rng.standard_normal((nq, DIM2)).astype(np.float32)
    ranker = Ranker.rrf(25.0) if seed % 2 else Ranker.weighted()
    w = [float(rng.uniform(0.2, 1.0)), float(rng.uniform(0.2, 1.0))]
    res = coll.search(
        SearchRequest(
            anns=[AnnsQuery("vector", q1, weight=w[0]),
                  AnnsQuery("img_vec", q2, weight=w[1])],
            k=k, staleness_ms=0.0, ranker=ranker,
        )
    )
    want = oracle_hybrid(metric, [q1, q2], [v1, v2], w, k, ranker)
    for r in range(nq):
        assert res.pks[r].tolist() == want[r]

    # filtered single-field + radius vs oracle over the filtered base
    sel = price < 55
    s = field_scores(metric, q1, v1[sel])
    if metric is Metric.L2:
        radius = float(np.sort(s[0])[8]) + 1e-5
    else:
        radius = float(np.sort(s[0])[-9]) - 1e-5
    fres = coll.search(
        q1, limit=k, staleness_ms=0.0, filter_expr="price < 55", radius=radius
    )
    sel_ids = np.nonzero(sel)[0]
    want_rng = oracle_range(metric, q1, v1[sel], k, radius)
    for r in range(nq):
        live = fres.pks[r][fres.pks[r] >= 0]
        assert live.tolist() == [int(sel_ids[i]) for i in want_rng[r]]
