"""Segment MVCC + delta-consistency semantics (incl. hypothesis properties)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.consistency import ConsistencyLevel, GuaranteeTs, staleness_ms_of
from repro.core.segment import Segment, merge_segments
from repro.core.timestamp import INFINITE_STALENESS, pack


def make_segment(n=100, dim=8, ts_start=100):
    seg = Segment(1, "c", 0, dim)
    rng = np.random.default_rng(0)
    seg.append(
        np.arange(n),
        rng.standard_normal((n, dim)).astype(np.float32),
        np.arange(ts_start, ts_start + n, dtype=np.int64),
    )
    return seg


def test_visibility_by_timestamp():
    seg = make_segment(10, ts_start=100)
    assert seg.visible_mask(99).sum() == 0
    assert seg.visible_mask(104).sum() == 5  # rows ts 100..104
    assert seg.visible_mask(10_000).sum() == 10


def test_delete_mvcc():
    seg = make_segment(10, ts_start=100)
    seg.delete(np.array([3, 4]), ts=200)
    assert seg.visible_mask(150).sum() == 10  # before delete: all visible
    m = seg.visible_mask(250)
    assert m.sum() == 8 and not m[3] and not m[4]
    # time travel: a query pinned before the delete still sees the rows
    assert seg.visible_mask(199)[3]


@given(
    n=st.integers(1, 60),
    delete_frac=st.floats(0, 1),
    query_offset=st.integers(-5, 70),
)
@settings(max_examples=40, deadline=None)
def test_visibility_property(n, delete_frac, query_offset):
    """Property: visible(ts) == {rows inserted <= ts} - {deleted <= ts}."""
    seg = Segment(1, "c", 0, 4)
    rng = np.random.default_rng(1)
    ts_col = np.arange(100, 100 + n, dtype=np.int64)
    seg.append(np.arange(n), rng.standard_normal((n, 4)).astype(np.float32), ts_col)
    n_del = int(n * delete_frac)
    del_ts = 100 + n + 10
    if n_del:
        seg.delete(np.arange(n_del), ts=del_ts)
    q_ts = 100 + query_offset
    mask = seg.visible_mask(q_ts)
    for i in range(n):
        expected = ts_col[i] <= q_ts and not (i < n_del and del_ts <= q_ts)
        assert mask[i] == expected


def test_binlog_roundtrip_preserves_everything():
    seg = make_segment(50)
    seg.delete(np.array([7]), ts=500)
    seg.checkpoint_pos = 42
    seg.seal()
    blob = seg.to_binlog()
    seg2 = Segment.from_binlog("c", blob)
    assert seg2.num_rows == 50
    assert seg2.checkpoint_pos == 42
    np.testing.assert_array_equal(seg.pks(), seg2.pks())
    np.testing.assert_array_equal(seg.vectors(), seg2.vectors())
    np.testing.assert_array_equal(seg.visible_mask(10_000), seg2.visible_mask(10_000))


def test_merge_segments_drops_tombstones():
    a = make_segment(20, ts_start=100)
    b = make_segment(20, ts_start=300)
    a.delete(np.array([1, 2]), ts=400)
    a.seal(), b.seal()
    merged = merge_segments(99, [a, b])
    assert merged.num_rows == 38  # 40 - 2 deleted
    assert merged.state.value == "sealed"


def test_slices_and_tail():
    seg = Segment(1, "c", 0, 4, slice_rows=10)
    rng = np.random.default_rng(0)
    seg.append(np.arange(25), rng.standard_normal((25, 4)).astype(np.float32),
               np.arange(25, dtype=np.int64))
    assert seg.full_slices() == [0, 1]
    assert seg.slice_bounds(1) == (10, 20)
    assert seg.tail_rows() == (20, 25)


# ----------------------------------------------------------- delta guarantee
def test_guarantee_strong_vs_eventual():
    q_ts = pack(10_000, 0)
    strong = GuaranteeTs(query_ts=q_ts, staleness_ms=0.0)
    eventual = GuaranteeTs(query_ts=q_ts, staleness_ms=INFINITE_STALENESS)
    old_watermark = pack(9_000, 0)
    fresh_watermark = pack(10_001, 0)
    assert not strong.satisfied_by(old_watermark)
    assert strong.satisfied_by(fresh_watermark)
    assert eventual.satisfied_by(old_watermark)


@given(
    q_phys=st.integers(1_000, 1_000_000),
    lag_ms=st.integers(0, 10_000),
    tau=st.one_of(st.just(float("inf")), st.floats(0, 10_000)),
)
@settings(max_examples=100, deadline=None)
def test_guarantee_property(q_phys, lag_ms, tau):
    """Property: satisfied iff watermark lag < tau (or watermark >= query)."""
    q_ts = pack(q_phys, 0)
    wm = pack(q_phys - lag_ms, 0)
    g = GuaranteeTs(query_ts=q_ts, staleness_ms=tau)
    expected = (lag_ms < tau) or (wm >= q_ts)
    assert g.satisfied_by(wm) == expected
    # the wait target is the *minimal* satisfying watermark
    if not g.satisfied_by(wm) and tau != float("inf"):
        target = g.wait_target_ts()
        assert g.satisfied_by(target)
        if target >= (1 << 18):  # minimality check only when un-clamped
            assert not g.satisfied_by(target - (1 << 18))  # 1ms earlier fails


def test_session_consistency_read_your_writes():
    q_ts = pack(10_000, 0)
    write_ts = pack(10_500, 0)  # user's write is *after* query issue? no: before next read
    g = GuaranteeTs(query_ts=pack(11_000, 0), staleness_ms=INFINITE_STALENESS,
                    session_ts=write_ts)
    assert not g.satisfied_by(pack(10_400, 0))  # hasn't seen the write
    assert g.satisfied_by(pack(10_500, 0))


def test_consistency_levels():
    assert staleness_ms_of(ConsistencyLevel.STRONG) == 0
    assert staleness_ms_of(ConsistencyLevel.EVENTUAL) == INFINITE_STALENESS
    assert staleness_ms_of(ConsistencyLevel.BOUNDED, 1234.0) == 1234.0
