"""Batched IVF execution engine: fuzz equivalence against the scalar
per-list reference oracle (``REPRO_IVF_REFERENCE=1``), padded-probe edge
cases (tiny collections), and the ``search_batched`` candidate-pool
surface (including through ``QueryNode``)."""

import os

import numpy as np
import pytest

from repro.core.collection import Metric
from repro.index import IndexSpec, create_index
from repro.index.ivf import IVFFlatIndex
from repro.kernels import ops

KINDS = {
    "ivf_flat": {"nlist": 16},
    "ivf_sq": {"nlist": 16},
    "ivf_pq": {"nlist": 8, "m": 4, "ksub": 16},
}
METRICS = [Metric.L2, Metric.IP, Metric.COSINE]


def make_data(seed=7, n=800, d=32, nq=9):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((12, d)).astype(np.float32) * 3
    base = centers[rng.integers(0, 12, n)] + rng.standard_normal((n, d)).astype(
        np.float32
    )
    q = centers[rng.integers(0, 12, nq)] + rng.standard_normal((nq, d)).astype(
        np.float32
    )
    return base.astype(np.float32), q.astype(np.float32)


def reference(idx, q, k, valid=None):
    os.environ["REPRO_IVF_REFERENCE"] = "1"
    try:
        return idx.search(q, k, valid=valid)
    finally:
        del os.environ["REPRO_IVF_REFERENCE"]


def assert_topk_equiv(batched, ref, metric, atol=3e-3):
    """Top-k set parity at equal scores: same live count, same sorted
    score multiset, and any id disagreement confined to ties at the
    boundary (the k-th score)."""
    sb, ib = batched
    sr, ir = ref
    assert sb.shape == sr.shape and ib.shape == ir.shape
    for r in range(len(sb)):
        lb, lr = ib[r] >= 0, ir[r] >= 0
        assert lb.sum() == lr.sum(), f"row {r}: live counts differ"
        kb = np.sort(sb[r][lb] if metric is Metric.L2 else -sb[r][lb])
        kr = np.sort(sr[r][lr] if metric is Metric.L2 else -sr[r][lr])
        np.testing.assert_allclose(kb, kr, atol=atol, rtol=2e-4)
        only = set(ib[r][lb].tolist()) ^ set(ir[r][lr].tolist())
        if only:
            boundary = kb[-1]
            key = {}
            key.update(
                zip(ib[r][lb].tolist(), (sb[r][lb] if metric is Metric.L2 else -sb[r][lb]).tolist())
            )
            key.update(
                zip(ir[r][lr].tolist(), (sr[r][lr] if metric is Metric.L2 else -sr[r][lr]).tolist())
            )
            for pk in only:
                assert abs(key[pk] - boundary) <= atol + 1e-4 * abs(boundary), (
                    f"row {r}: id {pk} differs beyond a boundary tie"
                )


_built = {}


def build(kind, metric):
    if (kind, metric) not in _built:
        base, q = make_data()
        params = dict(KINDS[kind], nprobe=8)
        idx = create_index(IndexSpec(kind=kind, metric=metric, params=params))
        idx.build(base)
        _built[(kind, metric)] = (idx, base, q)
    return _built[(kind, metric)]


@pytest.mark.parametrize("kind", sorted(KINDS))
@pytest.mark.parametrize("metric", METRICS, ids=[m.value for m in METRICS])
@pytest.mark.parametrize("nprobe", [1, 8, "nlist"])
def test_batched_matches_reference(kind, metric, nprobe):
    idx, base, q = build(kind, metric)
    idx.params["nprobe"] = idx.nlist if nprobe == "nlist" else nprobe
    rng = np.random.default_rng(3)
    masks = [None, rng.random(len(base)) < 0.7]
    for valid in masks:
        got = idx.search(q, 10, valid=valid)
        want = reference(idx, q, 10, valid=valid)
        assert_topk_equiv(got, want, metric)


@pytest.mark.parametrize("kind", sorted(KINDS))
def test_batched_fuzz_with_deletes(kind):
    """Random shapes/masks, including sparse and empty visibility."""
    for seed in range(3):
        rng = np.random.default_rng(100 + seed)
        n = int(rng.integers(30, 400))
        d = 16
        base = rng.standard_normal((n, d)).astype(np.float32)
        q = rng.standard_normal((int(rng.integers(1, 6)), d)).astype(np.float32)
        params = dict(KINDS[kind])
        params["nlist"] = min(params["nlist"], max(2, n // 4))
        params["nprobe"] = int(rng.integers(1, params["nlist"] + 1))
        if kind == "ivf_pq":
            params["ksub"] = 8
        idx = create_index(IndexSpec(kind=kind, metric=Metric.L2, params=params))
        idx.build(base)
        k = int(rng.integers(1, 15))
        for valid in (None, rng.random(n) < 0.5, np.zeros(n, bool)):
            got = idx.search(q, k, valid=valid)
            want = reference(idx, q, k, valid=valid)
            assert_topk_equiv(got, want, Metric.L2, atol=1e-3)
            if valid is not None and not valid.any():
                assert (got[1] == -1).all()


def test_tiny_collection_padded_probes():
    """n < nlist: probes carry -1 padding; every row must still be found."""
    rng = np.random.default_rng(5)
    base = rng.standard_normal((5, 16)).astype(np.float32)
    q = rng.standard_normal((3, 16)).astype(np.float32)
    for kind in ("ivf_flat", "ivf_sq"):
        idx = create_index(
            IndexSpec(kind=kind, metric=Metric.L2, params={"nlist": 64, "nprobe": 8})
        )
        idx.build(base)
        s, i = idx.search(q, 10)
        for r in range(len(q)):
            assert set(i[r][i[r] >= 0].tolist()) == set(range(5)), kind
        # nprobe param raised beyond nlist after build: same, via -1 pads
        idx.params["nprobe"] = 999
        s, i = idx.search(q, 10)
        for r in range(len(q)):
            assert set(i[r][i[r] >= 0].tolist()) == set(range(5)), kind
        # reference oracle agrees on the padded-probe edge
        assert_topk_equiv((s, i), reference(idx, q, 10), Metric.L2)


def test_search_empty_query_batch():
    idx, base, q = build("ivf_flat", Metric.L2)
    s, i = idx.search(np.zeros((0, base.shape[1]), np.float32), 5)
    assert s.shape == (0, 5) and i.shape == (0, 5)


def test_search_batched_pools_match_per_index_search():
    """Each unit's candidate-pool block, reduced with merge_topk, must
    equal that unit's own search()."""
    rng = np.random.default_rng(11)
    idxs = []
    for u in range(3):
        base = rng.standard_normal((300 + 40 * u, 16)).astype(np.float32)
        ix = create_index(
            IndexSpec(kind="ivf_flat", metric=Metric.L2, params={"nlist": 8, "nprobe": 4})
        )
        ix.build(base)
        idxs.append(ix)
    q = rng.standard_normal((6, 16)).astype(np.float32)
    s, i, splits = IVFFlatIndex.search_batched(idxs, q, 7)
    assert len(splits) == len(idxs) + 1 and splits[0] == 0
    for u, ix in enumerate(idxs):
        blk = slice(splits[u], splits[u + 1])
        ms, mi = ops.merge_topk(s[:, blk], i[:, blk], 7, metric="l2")
        ss, si = ix.search(q, 7)
        assert_topk_equiv((ms, mi), (ss, si), Metric.L2, atol=1e-4)


def test_search_batched_reference_flag_falls_back():
    """REPRO_IVF_REFERENCE=1 routes search_batched through per-index
    scalar searches (blocks of width k)."""
    rng = np.random.default_rng(12)
    base = rng.standard_normal((200, 16)).astype(np.float32)
    ix = create_index(
        IndexSpec(kind="ivf_flat", metric=Metric.L2, params={"nlist": 8, "nprobe": 8})
    )
    ix.build(base)
    q = rng.standard_normal((4, 16)).astype(np.float32)
    os.environ["REPRO_IVF_REFERENCE"] = "1"
    try:
        s, i, splits = IVFFlatIndex.search_batched([ix, ix], q, 5)
    finally:
        del os.environ["REPRO_IVF_REFERENCE"]
    assert splits == [0, 5, 10]
    ss, si = reference(ix, q, 5)
    np.testing.assert_array_equal(i[:, :5], si)
    np.testing.assert_array_equal(i[:, 5:], si)


def test_query_node_indexed_equivalence_with_deletes():
    """Node-level search over sealed+indexed segments (grouped
    search_batched dispatch) matches the reference oracle path, with
    delta-deletes in play."""
    from repro.core.consistency import GuaranteeTs
    from repro.core.log import LogBroker
    from repro.core.object_store import MemoryObjectStore
    from repro.core.query_node import QueryNode, SealedHandle
    from repro.core.segment import Segment
    from repro.core.timestamp import INFINITE_STALENESS

    rng = np.random.default_rng(21)
    dim, n_seg, rows = 24, 4, 300
    node = QueryNode("qn-ivf", LogBroker(), MemoryObjectStore())
    base = rng.standard_normal((n_seg * rows, dim)).astype(np.float32)
    for sid in range(n_seg):
        lo = sid * rows
        seg = Segment(sid, "c", 0, dim)
        seg.append(
            np.arange(lo, lo + rows),
            base[lo : lo + rows],
            np.full(rows, 100, np.int64),
        )
        idx = create_index(
            IndexSpec(kind="ivf_flat", metric=Metric.L2, params={"nlist": 8, "nprobe": 8})
        )
        idx.build(base[lo : lo + rows])
        node.sealed[("c", sid)] = SealedHandle(seg, index=idx, index_kind="ivf_flat")
    # delete a slice of pks across segments
    doomed = rng.choice(n_seg * rows, 80, replace=False)
    node.delta_deletes["c"] = {int(pk): 200 for pk in doomed}
    q = rng.standard_normal((7, dim)).astype(np.float32)
    g = GuaranteeTs(query_ts=10_000, staleness_ms=INFINITE_STALENESS)

    got = node.search("c", q, 10, Metric.L2, g)
    os.environ["REPRO_IVF_REFERENCE"] = "1"
    try:
        want = node.search("c", q, 10, Metric.L2, g)
    finally:
        del os.environ["REPRO_IVF_REFERENCE"]
    assert_topk_equiv(got, want, Metric.L2)
    assert not set(got[1][got[1] >= 0].ravel().tolist()) & set(doomed.tolist())
