"""Per-architecture smoke tests (reduced configs, CPU forward/train step)
and prefill/decode parity — the correctness backbone of the model zoo."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cells, skipped_cells
from repro.models import model as M
from repro.models.config import SHAPES as SHAPE_TABLE

ALL_ARCHS = sorted(ARCHS)


def setup_reduced(name, B=2, S=12, seed=0):
    cfg = ARCHS[name].reduced()
    params = M.init_params(cfg, jax.random.key(seed))
    tokens = jax.random.randint(jax.random.key(seed + 1), (B, S), 0, cfg.vocab_size)
    prefix = None
    if cfg.frontend == "vlm_stub":
        prefix = jax.random.normal(
            jax.random.key(seed + 2), (B, cfg.num_prefix_embeddings, cfg.d_model),
            jnp.float32,
        )
    return cfg, params, tokens, prefix


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(name):
    cfg, params, tokens, prefix = setup_reduced(name)
    logits = M.forward(cfg, params, tokens, prefix, remat=False)
    total = tokens.shape[1] + (prefix.shape[1] if prefix is not None else 0)
    assert logits.shape == (2, total, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step_decreases_loss(name):
    """One real optimizer step on CPU must run and produce finite loss."""
    from repro.train.optimizer import AdamWConfig, adamw_update, clip_by_global_norm, init_opt_state

    cfg, params, tokens, prefix = setup_reduced(name)
    labels = jnp.roll(tokens, -1, axis=1)
    opt = init_opt_state(params)
    adamw = AdamWConfig(lr=1e-2, warmup_steps=1)

    def loss_fn(p):
        return M.lm_loss(cfg, p, tokens, labels, prefix, remat=True, seq_chunk=8)

    l0, grads = jax.value_and_grad(loss_fn)(params)
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    params2, opt = adamw_update(adamw, params, grads, opt)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0), f"{name}: loss {l0} -> {l1}"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_parity(name):
    cfg, params, tokens, prefix = setup_reduced(name)
    B, S = tokens.shape
    P = prefix.shape[1] if prefix is not None else 0
    cache = M.init_cache(cfg, B, P + S + 4)
    pre_logits, cache = M.prefill(cfg, params, tokens, cache, prefix, remat=False)
    toks2 = jax.random.randint(jax.random.key(9), (B, 3), 0, cfg.vocab_size)
    full = jnp.concatenate([tokens, toks2], axis=1)
    ref = M.forward(cfg, params, full, prefix, remat=False)
    ref_cmp = ref[:, P:, :]
    pre_cmp = pre_logits[:, P:, :] if P else pre_logits
    np.testing.assert_allclose(
        np.asarray(pre_cmp), np.asarray(ref_cmp[:, :S]), rtol=3e-2, atol=3e-2
    )
    c = cache
    for t in range(3):
        lg, c = M.decode_step(cfg, params, c, full[:, S + t : S + t + 1])
        err = np.abs(np.asarray(lg[:, 0]) - np.asarray(ref_cmp[:, S + t])).max()
        assert err < 0.15, f"{name} decode step {t}: err {err}"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_remat_matches_no_remat(name):
    cfg, params, tokens, prefix = setup_reduced(name)
    a = M.forward(cfg, params, tokens, prefix, remat=False)
    b = M.forward(cfg, params, tokens, prefix, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_param_counts_match_reference():
    """Analytic parameter counts must be near the published model sizes."""
    expected = {
        "yi-9b": 8.8e9, "qwen3-32b": 32.8e9, "minicpm3-4b": 4.2e9,
        "qwen1.5-4b": 4.0e9, "paligemma-3b": 3.0e9,
        "qwen3-moe-30b-a3b": 30.5e9, "deepseek-moe-16b": 16.9e9,
        "mamba2-370m": 0.42e9, "musicgen-medium": 1.8e9,
        "jamba-v0.1-52b": 51.5e9,
    }
    for name, n in expected.items():
        got = ARCHS[name].num_params()
        assert abs(got - n) / n < 0.12, f"{name}: {got/1e9:.2f}B vs {n/1e9:.2f}B"


def test_moe_active_params():
    cfg = ARCHS["qwen3-moe-30b-a3b"]
    assert cfg.active_params() < 0.15 * cfg.num_params()


def test_cell_table_covers_assignment():
    runnable = cells()
    assert len(runnable) == 32  # 10 archs x 3 shapes + 2 long_500k
    skipped = skipped_cells()
    assert len(skipped) == 8
    assert all(s[1] == "long_500k" for s in skipped)
    # long_500k runs exactly for the sub-quadratic archs
    long_archs = {a for a, s in runnable if s == "long_500k"}
    assert long_archs == {"mamba2-370m", "jamba-v0.1-52b"}


def test_moe_capacity_drop_semantics():
    """Over-capacity tokens are dropped, under-capacity ones are exact."""
    from repro.models.moe import init_moe_params, moe_block

    import dataclasses

    cfg = ARCHS["qwen3-moe-30b-a3b"].reduced()
    cfg_tight = dataclasses.replace(cfg, moe_capacity_factor=0.01)
    p = init_moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    full = moe_block(cfg, p, x)
    tight = moe_block(cfg_tight, p, x)
    assert not np.allclose(np.asarray(full), np.asarray(tight))
    # tight capacity zeroes most contributions
    assert np.abs(np.asarray(tight)).mean() < np.abs(np.asarray(full)).mean()


def test_ssm_state_continuity():
    """Prefill state -> decode continues exactly like one longer prefill."""
    from repro.models import ssm as S

    cfg = ARCHS["mamba2-370m"].reduced()
    p = S.init_ssm_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 17, cfg.d_model), jnp.bfloat16) * 0.1
    y_full = S.ssm_block(cfg, p, x)
    y_pre, state = S.ssm_block_with_state(cfg, p, x[:, :16], {})
    y_dec, _ = S.ssm_decode_step(cfg, p, x[:, 16:17], state)
    err = np.abs(np.asarray(y_dec, np.float32) - np.asarray(y_full[:, 16:17], np.float32)).max()
    assert err < 0.05, err
