"""Serving-tier request scheduler: async micro-batched ingest with
credit-based backpressure, read micro-batching over typed requests, and
watermark-aware bounded-staleness routing."""

import os

import numpy as np
import pytest

from repro.core import (
    AdmissionRejected,
    ConsistencyLevel,
    DeleteRequest,
    FaultInjector,
    FieldSchema,
    FieldType,
    GuaranteeTs,
    InsertRequest,
    ManuConfig,
    ManuSystem,
    Schema,
    SearchRequest,
)
from repro.core.consistency import staleness_ms_of
from repro.core.log import dml_channel
from repro.core.timestamp import INFINITE_STALENESS, pack, physical_of

DIM = 16


def make_system(**over):
    kw = dict(num_query_nodes=2, seal_rows=100_000, num_shards=2)
    kw.update(over)
    return ManuSystem(ManuConfig(**kw))


def vecs(rng, n):
    return {"vector": rng.standard_normal((n, DIM)).astype(np.float32)}


# ---------------------------------------------------------------------------
# async write path: tickets, one LSN per request, explicit/result flush
# ---------------------------------------------------------------------------


def test_insert_async_tickets_resolve_with_own_results(rng):
    system = make_system()
    coll = system.create_collection("c", dim=DIM)
    sizes = [7, 13, 5]
    tickets = [coll.insert_async(vecs(rng, n)) for n in sizes]
    assert not any(t.done for t in tickets)  # queued, not yet flushed
    assert system.scheduler.pending_write_rows("c") == sum(sizes)

    flushed = system.flush_ingest()
    assert flushed == len(sizes)
    results = [t.result() for t in tickets]
    # each original request keeps its OWN LSN and row count
    assert [r.row_count for r in results] == sizes
    lsns = [r.watermark_ts for r in results]
    assert len(set(lsns)) == len(sizes)
    assert lsns == sorted(lsns)  # queue order preserved within the batch
    # exactly ONE WAL-entry-point crossing for the whole batch
    assert system.telemetry.counter_value("logger_batches_total") == 1.0

    system.run_until_idle()
    assert coll.num_entities() == sum(sizes)
    # session read-your-writes covers the async watermark
    res = coll.search(rng.standard_normal((1, DIM)).astype(np.float32),
                      limit=5, read_your_writes=True)
    assert res.pks.shape == (1, 5)


def test_ticket_result_force_flushes_own_queue(rng):
    system = make_system()
    coll = system.create_collection("c", dim=DIM)
    ticket = coll.insert_async(vecs(rng, 9))
    assert not ticket.done
    res = ticket.result()  # no explicit flush_ingest: result() forces it
    assert res.row_count == 9
    system.run_until_idle()
    assert coll.num_entities() == 9


def test_collection_flush_drains_scheduler_queue(rng):
    system = make_system()
    coll = system.create_collection("c", dim=DIM)
    coll.insert_async(vecs(rng, 20))
    coll.flush()  # must include admitted-but-unflushed rows
    assert coll.num_entities() == 20


# ---------------------------------------------------------------------------
# backpressure: typed admission rejection + credit recovery
# ---------------------------------------------------------------------------


def test_admission_rejected_is_typed_and_credits_recover(rng):
    system = make_system(ingest_queue_rows=100, ingest_flush_rows=10_000,
                         ingest_flush_ms=1e9)
    coll = system.create_collection("c", dim=DIM)
    first = coll.insert_async(vecs(rng, 60))
    with pytest.raises(AdmissionRejected) as ei:
        coll.insert_async(vecs(rng, 50))
    err = ei.value
    assert err.collection == "c"
    assert err.shard == 0  # auto-pk batches route to shard 0
    assert err.pending_rows == 60
    assert err.capacity_rows == 100
    assert err.request_rows == 50
    assert system.telemetry.counter_value("sched_rejected_total") == 1.0

    # flushing returns the credits; the same request is then admitted
    system.flush_ingest()
    assert first.done
    retry = coll.insert_async(vecs(rng, 50))
    assert retry.result().row_count == 50


def test_oversize_request_admitted_only_into_empty_queue(rng):
    system = make_system(ingest_queue_rows=100, ingest_flush_rows=10_000,
                         ingest_flush_ms=1e9)
    coll = system.create_collection("c", dim=DIM)
    big = coll.insert_async(vecs(rng, 300))  # > capacity, queue empty: admit
    coll.insert_async(vecs(rng, 300))  # capacity already spent: reject
    system.flush_ingest()
    assert big.result().row_count == 300


# ---------------------------------------------------------------------------
# flush triggers: depth (at submit) and age (via pump)
# ---------------------------------------------------------------------------


def test_depth_trigger_flushes_at_flush_rows(rng):
    system = make_system(ingest_flush_rows=32, ingest_flush_ms=1e9)
    coll = system.create_collection("c", dim=DIM)
    t1 = coll.insert_async(vecs(rng, 16))
    assert not t1.done  # 16 < 32: still queued
    t2 = coll.insert_async(vecs(rng, 16))
    # 32 rows accumulated: the depth trigger flushed synchronously
    assert t1.done and t2.done
    assert system.telemetry.counter_value(
        "sched_flushes_total", {"trigger": "depth"}) == 1.0


def test_age_trigger_flushes_via_pump(rng):
    system = make_system(ingest_flush_ms=20.0)
    coll = system.create_collection("c", dim=DIM)
    ticket = coll.insert_async(vecs(rng, 4))
    system.pump()
    assert not ticket.done  # age 0ms < 20ms
    system.clock.advance(25)
    system.pump()
    assert ticket.done
    assert system.telemetry.counter_value(
        "sched_flushes_total", {"trigger": "age"}) == 1.0


def test_threaded_age_trigger_resolves_without_forcing(rng):
    system = make_system(manual_clock=False, threaded=True,
                         ingest_flush_ms=5.0, num_query_nodes=1, num_shards=1)
    try:
        coll = system.create_collection("c", dim=DIM)
        ticket = coll.insert_async(vecs(rng, 8))
        # wait() never forces a flush: only the pump loop's age trigger
        # can resolve this ticket
        assert ticket.wait(5.0)
        assert ticket.result().row_count == 8
        system.wait_idle()
        assert coll.num_entities() == 8
    finally:
        system.stop_threads()


# ---------------------------------------------------------------------------
# read micro-batching: typed requests group by plan shape, split exactly
# ---------------------------------------------------------------------------


def test_batching_proxy_typed_requests_match_single_shot(rng):
    system = make_system(seal_rows=200, slice_rows=64)
    coll = system.create_collection(
        "c", dim=DIM,
        extra_fields=[FieldSchema("price", FieldType.FLOAT),
                      FieldSchema("label", FieldType.STRING)],
    )
    n = 500
    rows = vecs(rng, n)
    rows["price"] = rng.uniform(0, 100, n)
    rows["label"] = rng.choice(["a", "b"], n)
    coll.insert(rows)
    coll.flush()  # sealed + growing mix
    coll.insert({"vector": rng.standard_normal((80, DIM)).astype(np.float32),
                 "price": rng.uniform(0, 100, 80),
                 "label": rng.choice(["a", "b"], 80)})

    requests = [
        SearchRequest.single(
            rng.standard_normal((1, DIM)).astype(np.float32), field="vector",
            k=8, staleness_ms=0.0, filter="price < 50 and label == 'a'",
            output_fields=("price",),
        )
        for _ in range(3)
    ] + [
        SearchRequest.single(
            rng.standard_normal((2, DIM)).astype(np.float32), field="vector",
            k=5, staleness_ms=0.0,
        )
        for _ in range(2)
    ]
    for req in requests:
        system.batcher.submit_request(coll.info, req)
    batches_before = system.telemetry.counter_value("sched_search_batches_total")
    batched = system.batcher.flush(wait_fn=system._cooperative_wait)
    # two distinct plan shapes -> exactly two proxy searches
    assert (system.telemetry.counter_value("sched_search_batches_total")
            - batches_before) == 2.0

    for req, got in zip(requests, batched):
        want = coll.search(request=req)
        np.testing.assert_array_equal(got.pks, want.pks)
        np.testing.assert_allclose(got.scores, want.scores, rtol=1e-5)
        if req.output_fields:
            assert got.fields is not None
            np.testing.assert_allclose(
                got.fields["price"], want.fields["price"], rtol=1e-6)
        assert got.pks.shape[0] == req.nq  # split matches each request's nq


def test_batching_proxy_legacy_tuple_surface_survives(rng):
    system = make_system()
    coll = system.create_collection("c", dim=DIM)
    coll.insert(vecs(rng, 300))
    system.run_until_idle()
    qs = rng.standard_normal((4, DIM)).astype(np.float32)
    for r in range(4):
        system.batcher.submit(coll.info, qs[r:r + 1], 3,
                              GuaranteeTs(system.tso.next(), 0.0))
    out = system.batcher.flush(wait_fn=system._cooperative_wait)
    want = coll.search(qs, limit=3, staleness_ms=0.0)
    for r in range(4):
        np.testing.assert_array_equal(out[r].pks[0], want.pks[r])


def test_read_batch_executes_under_strictest_guarantee(rng):
    system = make_system(num_shards=1, num_query_nodes=1)
    coll = system.create_collection("c", dim=DIM)
    coll.insert(vecs(rng, 100))
    system.run_until_idle()
    res = system.proxy.mutate(coll.info, InsertRequest(vecs(rng, 40)))
    q = rng.standard_normal((1, DIM)).astype(np.float32)
    # an EVENTUAL ticket groups with a STRONG-by-session one; the batch
    # must satisfy the session watermark for BOTH slices
    i_loose = system.batcher.submit_request(
        coll.info, SearchRequest.single(q, field="vector", k=20),
        guarantee=GuaranteeTs(system.tso.next(), INFINITE_STALENESS),
    )
    i_strict = system.batcher.submit_request(
        coll.info, SearchRequest.single(q, field="vector", k=20),
        guarantee=GuaranteeTs(system.tso.next(), INFINITE_STALENESS,
                              session_ts=res.watermark_ts),
    )
    out = system.batcher.flush(wait_fn=system._cooperative_wait)
    for i in (i_loose, i_strict):
        assert set(res.pks.tolist()) & set(out[i].pks[0].tolist())


# ---------------------------------------------------------------------------
# watermark-aware routing: covered replicas serve bounded reads with no wait
# ---------------------------------------------------------------------------


def test_covered_replica_serves_read_with_zero_wait_bit_for_bit(rng):
    system = make_system(num_query_nodes=2, num_shards=1, num_loggers=1,
                         replication_factor=2)
    coll = system.create_collection("c", dim=DIM)
    coll.insert(vecs(rng, 200))
    system.run_until_idle()

    ch = dml_channel("c", 0)
    coord = system.query_coord
    owner = next(n for n, st in coord.nodes.items() if ch in st.channels)
    followers = sorted(coord.channel_followers.get(ch, ()))
    assert followers and owner not in followers
    follower = followers[0]

    # Diverge the replicas: write through the proxy (no pump), force a
    # tick, and let ONLY the follower consume it.
    res = system.proxy.mutate(coll.info, InsertRequest(vecs(rng, 50)))
    for lg in system.loggers:
        lg.tick([ch], force=True)
    fnode = system.query_nodes[follower]
    while fnode.step():
        pass
    assert system.proxy._channel_watermark(follower, ch) >= res.watermark_ts
    assert system.proxy._channel_watermark(owner, ch) < res.watermark_ts

    guarantee = GuaranteeTs(system.tso.next(), INFINITE_STALENESS,
                            session_ts=res.watermark_ts)
    req = SearchRequest.single(
        rng.standard_normal((2, DIM)).astype(np.float32), field="vector", k=10)
    wait_calls = []

    def recording_wait(node, g, channels=None):
        wait_calls.append((node.node_id, channels))

    covered_before = system.telemetry.counter_value(
        "consistency_routes_total", {"outcome": "covered"})
    routed = system.proxy.search(coll.info, req, guarantee=guarantee,
                                 wait_fn=recording_wait)
    assert system.telemetry.counter_value(
        "consistency_routes_total", {"outcome": "covered"}) == covered_before + 1
    # the covering follower served the read: nobody waited at all
    assert wait_calls == []
    assert set(res.pks.tolist()) & set(routed.pks.flatten().tolist())

    # Wait-based path for the SAME guarantee (followers hidden so the
    # lagging owner must wait): results are bit-for-bit identical.
    saved, coord.channel_followers = coord.channel_followers, {}
    try:
        waited = system.proxy.search(coll.info, req, guarantee=guarantee,
                                     wait_fn=system._cooperative_wait)
    finally:
        coord.channel_followers = saved
    np.testing.assert_array_equal(routed.pks, waited.pks)
    np.testing.assert_array_equal(routed.scores, waited.scores)
    assert system.telemetry.counter_value(
        "consistency_routes_total", {"outcome": "waited"}) >= 1.0


def test_lagging_owner_dispatched_for_sealed_does_not_resurrect_deletes(rng):
    """A node dispatched only for sealed units whose channel was routed to
    a fresher covering replica must NOT serve its own lagging growing copy:
    tombstones are per-node, so rows deleted before the wait target would
    resurface in the merged top-k (pk-dedup cannot remove them)."""
    schema = Schema((
        FieldSchema("pk", FieldType.INT, is_primary=True),
        FieldSchema("vector", FieldType.VECTOR, dim=DIM),
    ))
    system = make_system(num_query_nodes=2, num_shards=1, num_loggers=1,
                         replication_factor=2, seal_rows=64)
    coll = system.create_collection("c", dim=DIM, schema=schema)
    # Two seal-sized inserts -> two sealed segments, so the load-spread
    # sealed picks give BOTH nodes a unit (the lagging owner included).
    for lo in (0, 64):
        coll.insert({"pk": np.arange(lo, lo + 64), **vecs(rng, 64)})
        system.run_until_idle()
    assert len(system.query_coord.placement_for("c")) >= 2

    # Growing rows consumed by BOTH replicas.
    gpks = np.arange(200, 230)
    gvecs = vecs(rng, 30)
    coll.insert({"pk": gpks, **gvecs})
    system.run_until_idle()

    ch = dml_channel("c", 0)
    coord = system.query_coord
    owner = next(n for n, st in coord.nodes.items() if ch in st.channels)
    followers = sorted(coord.channel_followers.get(ch, ()))
    assert followers and owner not in followers
    follower = followers[0]

    # Delete the growing rows; force a tick and let ONLY the follower
    # consume it — the owner's growing copy keeps the rows visible.
    del_res = system.proxy.mutate(coll.info, DeleteRequest(gpks))
    for lg in system.loggers:
        lg.tick([ch], force=True)
    fnode = system.query_nodes[follower]
    while fnode.step():
        pass
    assert system.proxy._channel_watermark(follower, ch) >= del_res.watermark_ts
    assert system.proxy._channel_watermark(owner, ch) < del_res.watermark_ts

    guarantee = GuaranteeTs(system.tso.next(), INFINITE_STALENESS,
                            session_ts=del_res.watermark_ts)
    # Query AT the deleted vectors: a resurrected row would rank first.
    req = SearchRequest.single(gvecs["vector"][:2], field="vector", k=10)
    wait_calls = []

    def recording_wait(node, g, channels=None):
        wait_calls.append((node.node_id, channels))

    before = system.query_nodes[owner].search_count
    res = system.proxy.search(coll.info, req, guarantee=guarantee,
                              wait_fn=recording_wait)
    # The lagging owner DID serve sealed units for this request...
    assert system.query_nodes[owner].search_count == before + 1
    # ...but its un-tombstoned growing copy never reached the merge, and
    # the covering follower kept the read zero-wait.
    assert not (set(gpks.tolist()) & set(res.pks.flatten().tolist()))
    assert wait_calls == []


def test_scoped_wait_returns_when_channel_not_assigned(rng):
    """A scoped consistency wait on a channel the coordinator no longer
    (or never) assigned to the node must return instead of pumping to the
    round limit: no subscribe will ever land, and the channel's actual
    owner runs its own wait."""
    system = make_system(num_shards=1)
    coll = system.create_collection("c", dim=DIM)
    coll.insert(vecs(rng, 10))
    system.run_until_idle()
    node = next(iter(system.query_nodes.values()))
    strong = GuaranteeTs(system.tso.next(), 0.0)  # nothing satisfies yet
    system._cooperative_wait(node, strong, ["dml/c/99"])  # must not hang


def test_sync_mutate_drains_pending_async_writes(rng):
    """A sync mutation must not overtake async mutations admitted earlier:
    insert_async(pk) followed by a sync delete(pk) has to apply in
    admission order, or the delete lands first and the row resurrects."""
    schema = Schema((
        FieldSchema("pk", FieldType.INT, is_primary=True),
        FieldSchema("vector", FieldType.VECTOR, dim=DIM),
    ))
    system = make_system(num_shards=1)
    coll = system.create_collection("c", dim=DIM, schema=schema)
    ticket = coll.insert_async({"pk": np.arange(8), **vecs(rng, 8)})
    assert not ticket.done
    lsn = coll.delete(np.arange(8))  # sync: drains the queue first
    assert ticket.done
    assert ticket.result().watermark_ts < lsn  # WAL order = admission order
    system.run_until_idle()
    # The delete applied AFTER the insert: every row is tombstoned, so a
    # read-your-writes search over the whole collection comes back empty.
    res = coll.search(rng.standard_normal((1, DIM)).astype(np.float32),
                      limit=8, read_your_writes=True)
    assert set(res.pks.flatten().tolist()) == {-1}


# ---------------------------------------------------------------------------
# GuaranteeTs.wait_target_ts edge cases
# ---------------------------------------------------------------------------


def test_wait_target_ts_edge_cases():
    ts = pack(10_000, 5)

    # INFINITE staleness: eventual — wait only for the session watermark
    g = GuaranteeTs(ts, INFINITE_STALENESS)
    assert g.wait_target_ts() == 0
    g = GuaranteeTs(ts, INFINITE_STALENESS, session_ts=123)
    assert g.wait_target_ts() == 123

    # zero staleness (STRONG): wait for the query timestamp itself
    g = GuaranteeTs(ts, 0.0)
    assert g.wait_target_ts() == ts
    assert g.satisfied_by(ts) and not g.satisfied_by(ts - 1)

    # session + bounded interplay: the session watermark dominates when it
    # is ahead of the staleness-derived target
    tau = 100.0
    sess = pack(9_990, 0)  # inside the window, ahead of phys target
    g = GuaranteeTs(ts, tau, session_ts=sess)
    assert g.wait_target_ts() == sess
    assert not g.satisfied_by(sess - 1)  # read-your-writes still enforced

    # bounded without session: target sits tau behind the query timestamp
    g = GuaranteeTs(ts, tau)
    target = g.wait_target_ts()
    assert physical_of(target) == 10_000 - int(tau) + 1
    assert g.satisfied_by(target)

    # tau larger than the whole clock epoch: phys floor clamps to zero, so
    # ANY watermark satisfies the guarantee (pure eventual)
    g = GuaranteeTs(ts, 1e12)
    assert g.wait_target_ts() == 0
    assert g.satisfied_by(0)

    # named-level resolution backing the config knob
    assert staleness_ms_of(ConsistencyLevel.BOUNDED, bounded_ms=750.0) == 750.0
    assert staleness_ms_of(ConsistencyLevel.STRONG) == 0.0
    assert staleness_ms_of(ConsistencyLevel.EVENTUAL) == INFINITE_STALENESS


# ---------------------------------------------------------------------------
# chaos-matrix probe: backpressure + transient faults lose/duplicate nothing
# ---------------------------------------------------------------------------


def test_backpressure_under_faults_loses_and_duplicates_nothing():
    seed = int(os.environ.get("REPRO_CHAOS_SEED", "42"))
    inj = FaultInjector(seed=seed)
    inj.transient("log.publish", 0.03)
    inj.transient("object_store.put", 0.05)
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, num_shards=2, seal_rows=100_000,
                   ingest_queue_rows=128, ingest_flush_rows=10_000,
                   ingest_flush_ms=1e9),
        injector=inj,
    )
    rng = np.random.default_rng(seed)
    coll = system.create_collection("c", dim=DIM)

    tickets, total_rows, rejections = [], 0, 0
    for _ in range(40):
        rows = vecs(rng, int(rng.integers(1, 48)))
        try:
            tickets.append(coll.insert_async(rows))
        except AdmissionRejected:
            rejections += 1
            system.flush_ingest()  # returns credits; retry must be admitted
            tickets.append(coll.insert_async(rows))
        total_rows += rows["vector"].shape[0]
    assert rejections > 0  # the probe exercised a full queue
    system.flush_ingest()

    results = [t.result() for t in tickets]
    lsns = [r.watermark_ts for r in results]
    assert len(set(lsns)) == len(tickets)  # no duplicated LSNs
    all_pks = np.concatenate([r.pks for r in results])
    assert len(np.unique(all_pks)) == total_rows  # no lost/duplicated rows
    system.run_until_idle()
    assert coll.num_entities() == total_rows
