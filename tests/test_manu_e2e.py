"""End-to-end behaviour of the Manu system: ingestion through the log
backbone, delta consistency, sealing/indexing, failover, elasticity,
time travel, filtering, batching, dedup."""

import numpy as np
import pytest

from repro.core import FieldSchema, FieldType, ManuConfig, ManuSystem, Metric


def brute_force(base, queries, k):
    d = np.sum(queries**2, 1, keepdims=True) - 2 * queries @ base.T + np.sum(base**2, 1)
    return np.argsort(d, axis=1)[:, :k]


@pytest.fixture
def system():
    return ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=400, slice_rows=128,
                                 num_shards=2))


def ingest(coll, rng, n, dim, batches=4):
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    step = n // batches
    for i in range(batches):
        coll.insert({"vector": vecs[i * step : (i + 1) * step]})
    return vecs


def test_strong_consistency_sees_all_inserts(system, rng):
    coll = system.create_collection("c", dim=16)
    vecs = ingest(coll, rng, 1200, 16)
    q = rng.standard_normal((4, 16)).astype(np.float32)
    res = coll.search(q, limit=5, staleness_ms=0.0)
    gt = brute_force(vecs, q, 5)
    hits = sum(len(set(res.pks[r].tolist()) & set(gt[r].tolist())) for r in range(4))
    assert hits / 20 >= 0.9  # growing-slice temp index is approximate


def test_flush_seal_index_build_improves_to_exact(system, rng):
    coll = system.create_collection("c", dim=16)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 8, "nprobe": 8})
    vecs = ingest(coll, rng, 1200, 16)
    coll.flush()
    assert system.stats()["index_builds"] >= 2
    q = rng.standard_normal((4, 16)).astype(np.float32)
    res = coll.search(q, limit=5, staleness_ms=0.0)
    gt = brute_force(vecs, q, 5)
    hits = sum(len(set(res.pks[r].tolist()) & set(gt[r].tolist())) for r in range(4))
    assert hits / 20 == 1.0  # nprobe == nlist: exhaustive => exact


def test_deletes_respect_mvcc_and_time_travel(system, rng):
    coll = system.create_collection("c", dim=16)
    vecs = ingest(coll, rng, 800, 16)
    q = rng.standard_normal((1, 16)).astype(np.float32)
    before = coll.search(q, limit=5, staleness_ms=0.0)
    victims = before.pks[0][:2]
    coll.delete(victims)
    after = coll.search(q, limit=5, staleness_ms=0.0)
    assert not set(victims.tolist()) & set(after.pks[0].tolist())
    # time travel to before the delete resurrects them
    old = coll.search(q, limit=5, time_travel_ts=before.query_ts)
    assert set(victims.tolist()) <= set(old.pks[0].tolist())


def test_restore_collection_checkpoint_replay(system, rng):
    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 600, 8)
    coll.flush()
    system.checkpoint_collection("c")
    mark = system.tso.last_issued()
    coll.insert({"vector": rng.standard_normal((100, 8)).astype(np.float32)})
    coll.delete(np.arange(10))
    restored = system.restore_collection("c", mark)
    assert restored.num_rows() == 600  # no late insert, no late delete
    assert set(np.arange(10).tolist()) <= set(restored.pks().tolist())
    # restored snapshot is searchable
    q = rng.standard_normal((2, 8)).astype(np.float32)
    s, p = restored.search(q, 3)
    assert (p >= 0).all()


def test_query_node_failover_preserves_results(system, rng):
    coll = system.create_collection("c", dim=16)
    coll.create_index("vector", kind="flat")
    vecs = ingest(coll, rng, 1200, 16)
    coll.flush()
    q = rng.standard_normal((3, 16)).astype(np.float32)
    before = coll.search(q, limit=10, staleness_ms=0.0)

    victim = next(iter(system.query_coord.assignment.values()))
    system.kill_query_node(victim)
    dead = system.recover_failures()
    assert victim in dead
    after = coll.search(q, limit=10, staleness_ms=0.0)
    np.testing.assert_array_equal(
        np.sort(before.pks, axis=1), np.sort(after.pks, axis=1)
    )


def test_scale_up_down_rebalances(system, rng):
    coll = system.create_collection("c", dim=8, seal_rows=200)
    ingest(coll, rng, 1000, 8, batches=5)
    coll.flush()
    new_node = system.add_query_node()
    counts = {n: len(st.segments) for n, st in system.query_coord.nodes.items()}
    assert max(counts.values()) - min(counts.values()) <= 1
    q = rng.standard_normal((2, 8)).astype(np.float32)
    r1 = coll.search(q, limit=5, staleness_ms=0.0)
    system.remove_query_node(new_node)
    r2 = coll.search(q, limit=5, staleness_ms=0.0)
    np.testing.assert_array_equal(np.sort(r1.pks, 1), np.sort(r2.pks, 1))


def test_attribute_filtering(system, rng):
    coll = system.create_collection(
        "c", dim=8,
        extra_fields=[FieldSchema("price", FieldType.FLOAT)],
    )
    vecs = rng.standard_normal((500, 8)).astype(np.float32)
    price = rng.uniform(0, 100, 500).astype(np.float64)
    coll.insert({"vector": vecs, "price": price})
    q = rng.standard_normal((2, 8)).astype(np.float32)
    res = coll.query(q, limit=10, expr="price < 20", staleness_ms=0.0)
    live = res.pks[res.pks >= 0]
    assert len(live) and (price[live] < 20).all()


def test_read_your_writes_session(system, rng):
    coll = system.create_collection("c", dim=8)
    coll.insert({"vector": rng.standard_normal((50, 8)).astype(np.float32)})
    q = rng.standard_normal((1, 8)).astype(np.float32)
    res = coll.search(q, limit=5, read_your_writes=True)
    assert (res.pks[0] >= 0).sum() == 5


def test_batching_proxy_groups_requests(system, rng):
    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 400, 8, batches=2)
    qs = rng.standard_normal((6, 8)).astype(np.float32)
    from repro.core.consistency import GuaranteeTs
    from repro.core.timestamp import INFINITE_STALENESS

    for r in range(6):
        system.batcher.submit(coll.info, qs[r : r + 1], 4,
                              GuaranteeTs(system.tso.next(), 0.0))
    results = system.batcher.flush(wait_fn=system._cooperative_wait)
    assert len(results) == 6
    direct = coll.search(qs, limit=4, staleness_ms=0.0)
    for r in range(6):
        np.testing.assert_array_equal(results[r].pks[0], direct.pks[r])


def test_proxy_dedups_duplicate_segments(system, rng):
    """A segment may live on two nodes during redistribution — results must
    still contain unique pks (paper §3.6)."""
    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 600, 8)
    coll.flush()
    # force-load every sealed segment onto BOTH query nodes
    sealed = system.data_coord.sealed_segments("c")
    for node in system.query_nodes.values():
        for sid in sealed:
            node.load_sealed("c", sid)
    q = rng.standard_normal((2, 8)).astype(np.float32)
    res = coll.search(q, limit=10, staleness_ms=0.0)
    for r in range(2):
        live = res.pks[r][res.pks[r] >= 0]
        assert len(set(live.tolist())) == len(live)


def test_hedged_request_straggler(system, rng):
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 400, 8, batches=2)
    coll.flush()
    # make one node a straggler
    straggler = list(system.query_nodes.values())[0]
    straggler.inject_delay_s = 0.5
    q = rng.standard_normal((1, 8)).astype(np.float32)
    res = coll.search(q, limit=5, staleness_ms=0.0, hedge_timeout_s=0.05)
    assert (res.pks[0] >= 0).any()


def test_wal_to_binlog_column_equivalence(system, rng):
    """Data nodes' columnar binlog must reproduce the WAL rows exactly."""
    from repro.core.binlog import load_segment, read_binlog_column

    coll = system.create_collection("c", dim=8)
    vecs = ingest(coll, rng, 500, 8)
    coll.flush()
    sealed = system.data_coord.sealed_segments("c")
    assert sealed
    total = 0
    for sid in sealed:
        seg = load_segment(system.store, "c", sid)
        col = read_binlog_column(system.store, "c", sid, "vector")
        np.testing.assert_array_equal(seg.vectors(), col)
        pks = seg.pks()
        np.testing.assert_array_equal(vecs[pks], seg.vectors())
        total += seg.num_rows
    assert total == 500


def test_eventual_vs_strong_visibility(rng):
    """With no ticks pumped, eventual reads may miss fresh rows but strong
    reads must wait for them."""
    system = ManuSystem(ManuConfig(num_query_nodes=1, seal_rows=10_000,
                                   tick_interval_ms=1e12))  # ticks ~never fire
    coll = system.create_collection("c", dim=4)
    coll.insert({"vector": rng.standard_normal((20, 4)).astype(np.float32)})
    q = rng.standard_normal((1, 4)).astype(np.float32)
    res = coll.search(q, limit=5, staleness_ms=0.0)  # strong must still work
    assert (res.pks[0] >= 0).sum() == 5
